//! Per-client fair queuing between the connection readers and the
//! engine pool.
//!
//! The PR-3 front-end pushed every decoded request straight from its
//! connection's reader thread into the shared pool queue.  Arrival order
//! is a *hog's* order: one connection pipelining an unbounded open loop
//! fills the admission gate and the pool queue with its own work, and
//! every polite client's single request waits behind the whole backlog —
//! the same peripheral-contention failure ATRIA and Neural-PIM call out
//! for shared PIM resources.  This module puts a scheduler between the
//! readers and the pool:
//!
//! ```text
//!  reader A ──enqueue──▶ [queue A]╮
//!  reader B ──enqueue──▶ [queue B]┼─▶ fair scheduler ──▶ admission ──▶ pool
//!  reader C ──enqueue──▶ [queue C]╯    (one thread,        gate
//!                                       DRR or FIFO)
//! ```
//!
//! * Each client (connection) owns a **bounded FIFO queue**.  A full
//!   queue blocks only *that* client's reader — its TCP socket fills and
//!   the peer is throttled, while everyone else's queues keep draining.
//!   This is where a hog's flood now parks: in its own queue, not in
//!   front of other people's requests.
//! * One scheduler thread drains the queues.  Under
//!   [`FairnessPolicy::Drr`] (the default) it runs **deficit
//!   round-robin**: each runnable client earns `quantum` cost units per
//!   round and dispatches jobs while its deficit covers their cost, so
//!   over any window every backlogged client receives the same service
//!   share regardless of how deep its backlog is.  Unit-cost requests
//!   (the server dispatches every inference at cost 1) degenerate to
//!   exact per-request round-robin.  [`FairnessPolicy::Fifo`] preserves
//!   the old global arrival order — kept as the control knob that makes
//!   the fairness property measurable (and falsifiable) in benchmarks.
//! * **Starvation accounting**: every dispatch charges one "pass" to
//!   each other runnable, unblocked client; a client passed over more
//!   than `4 × runnable × quantum` (min 16) times in a row records one
//!   starvation event and resets.  DRR keeps every counter at zero by
//!   construction (property-tested); FIFO under a hog does not — the
//!   counter is how CI distinguishes the two.
//!
//! The scheduler is generic over the job payload so these mechanics are
//! unit-tested right here without sockets or pools; the server
//! instantiates it with its dispatch record (request id, row, pool
//! client, writer handle).

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::coordinator::ClientCounters;

/// How the scheduler orders dispatches across client queues.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FairnessPolicy {
    /// Deficit round-robin: equal service share per backlogged client.
    Drr,
    /// Global arrival order (the pre-fairness behavior): first come,
    /// first served, hogs included.
    Fifo,
}

impl FairnessPolicy {
    /// Parse a CLI spelling (`"drr"` | `"fifo"`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "drr" => Some(FairnessPolicy::Drr),
            "fifo" => Some(FairnessPolicy::Fifo),
            _ => None,
        }
    }
}

/// Scheduler configuration.
#[derive(Clone, Copy, Debug)]
pub struct FairnessConfig {
    /// Dispatch ordering policy.
    pub policy: FairnessPolicy,
    /// Cost units a client earns each DRR round (>= 1).  With the
    /// server's unit-cost requests this is the per-round burst length;
    /// 1 gives exact round-robin.
    pub quantum: u64,
    /// Per-client queue bound (>= 1).  A full queue blocks that
    /// client's reader — per-connection TCP backpressure.
    pub client_queue: usize,
}

impl Default for FairnessConfig {
    fn default() -> Self {
        FairnessConfig { policy: FairnessPolicy::Drr, quantum: 1, client_queue: 64 }
    }
}

/// Opaque handle to one registered client queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ClientId(u64);

/// Outcome of one [`FairScheduler::next`] call.
pub enum Next<T> {
    /// The fair choice: dispatch this job for this client.
    Job(ClientId, T),
    /// No dispatchable work appeared within the timeout.
    TimedOut,
    /// The scheduler was stopped; no more jobs will ever come.
    Stopped,
}

/// The scheduler rejected an operation because it is stopped or the
/// client is no longer registered.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Closed;

struct ClientQueue<T> {
    counters: Arc<ClientCounters>,
    /// `(arrival seq, cost, job)`, FIFO per client.
    jobs: VecDeque<(u64, u64, T)>,
    deficit: u64,
    passes: u64,
}

struct State<T> {
    clients: HashMap<u64, ClientQueue<T>>,
    /// Runnable (non-empty-queue) clients in round order; the front is
    /// the next DRR candidate.
    order: VecDeque<u64>,
    seq: u64,
    next_id: u64,
    stopped: bool,
}

struct Shared<T> {
    cfg: FairnessConfig,
    state: Mutex<State<T>>,
    /// Signalled when work arrives or the scheduler stops (wakes `next`).
    work: Condvar,
    /// Signalled when a queue drains, a client unregisters, or the
    /// scheduler stops (wakes blocked `enqueue` callers).
    space: Condvar,
}

/// Cloneable handle to one fair scheduler (see module docs).
pub struct FairScheduler<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for FairScheduler<T> {
    fn clone(&self) -> Self {
        FairScheduler { shared: Arc::clone(&self.shared) }
    }
}

impl<T> FairScheduler<T> {
    /// Lock the scheduler state, recovering the guard if a peer thread
    /// panicked mid-update (lock poisoning).  Every mutation below
    /// re-checks its invariants under the lock, so continuing with the
    /// recovered guard is sound — and a serving-path scheduler must not
    /// amplify one peer's panic into a panic on every reader thread.
    fn state(&self) -> MutexGuard<'_, State<T>> {
        self.shared.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Build a scheduler (quantum and queue bound are clamped to >= 1).
    pub fn new(mut cfg: FairnessConfig) -> Self {
        cfg.quantum = cfg.quantum.max(1);
        cfg.client_queue = cfg.client_queue.max(1);
        FairScheduler {
            shared: Arc::new(Shared {
                cfg,
                state: Mutex::new(State {
                    clients: HashMap::new(),
                    order: VecDeque::new(),
                    seq: 0,
                    next_id: 0,
                    stopped: false,
                }),
                work: Condvar::new(),
                space: Condvar::new(),
            }),
        }
    }

    /// Register a client queue; `counters` receives its enqueue /
    /// dispatch / starvation counts (share them with a
    /// [`MetricsHub`](crate::coordinator::MetricsHub) via
    /// `register_client`).
    pub fn register(&self, counters: Arc<ClientCounters>) -> ClientId {
        let mut g = self.state();
        let id = g.next_id;
        g.next_id += 1;
        g.clients.insert(
            id,
            ClientQueue { counters, jobs: VecDeque::new(), deficit: 0, passes: 0 },
        );
        ClientId(id)
    }

    /// Remove a client (connection closed): its queued jobs are dropped
    /// — work a dead peer can never receive must not consume pool
    /// capacity — and any reader blocked enqueueing for it wakes with
    /// [`Closed`].
    pub fn unregister(&self, id: ClientId) {
        let mut g = self.state();
        g.clients.remove(&id.0);
        g.order.retain(|&c| c != id.0);
        drop(g);
        self.shared.space.notify_all();
    }

    /// Queue one job for `id` at `cost` (clamped to >= 1; the server
    /// uses unit costs).  Blocks while the client's queue is full —
    /// per-connection backpressure — and returns [`Closed`] if the
    /// scheduler stops or the client unregisters while waiting.
    pub fn enqueue(&self, id: ClientId, cost: u64, job: T) -> Result<(), Closed> {
        let mut g = self.state();
        loop {
            if g.stopped {
                return Err(Closed);
            }
            let has_space = match g.clients.get(&id.0) {
                None => return Err(Closed),
                Some(q) => q.jobs.len() < self.shared.cfg.client_queue,
            };
            if has_space {
                break;
            }
            g = self.shared.space.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
        let seq = g.seq;
        g.seq += 1;
        // Split the guard so the queue borrow and the order list borrow
        // are field-precise (one deref borrow would conflict).
        let st = &mut *g;
        let Some(q) = st.clients.get_mut(&id.0) else {
            // Presence was checked above under this same lock hold, so
            // this arm is unreachable; report closure rather than panic.
            return Err(Closed);
        };
        let was_empty = q.jobs.is_empty();
        q.jobs.push_back((seq, cost.max(1), job));
        q.counters.record_enqueued();
        if was_empty {
            st.order.push_back(id.0);
        }
        drop(g);
        self.shared.work.notify_all();
        Ok(())
    }

    /// Pop the next job by the configured policy, skipping clients in
    /// `blocked` (the server passes connections whose writer queue is
    /// full so one non-reading peer cannot stall the scheduler).  Waits
    /// up to `timeout` for dispatchable work.
    pub fn next(&self, blocked: &[ClientId], timeout: Duration) -> Next<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.state();
        loop {
            if g.stopped {
                return Next::Stopped;
            }
            let popped = match self.shared.cfg.policy {
                FairnessPolicy::Drr => Self::pop_drr(&self.shared.cfg, &mut g, blocked),
                FairnessPolicy::Fifo => Self::pop_fifo(&self.shared.cfg, &mut g, blocked),
            };
            if let Some((id, job)) = popped {
                drop(g);
                self.shared.space.notify_all();
                return Next::Job(id, job);
            }
            let now = Instant::now();
            if now >= deadline {
                return Next::TimedOut;
            }
            g = self
                .shared
                .work
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(PoisonError::into_inner)
                .0;
        }
    }

    /// Stop the scheduler: every queue is dropped, every blocked
    /// `enqueue` and `next` wakes, and both report closure.
    pub fn stop(&self) {
        let mut g = self.state();
        g.stopped = true;
        g.clients.clear();
        g.order.clear();
        drop(g);
        self.shared.work.notify_all();
        self.shared.space.notify_all();
    }

    /// Jobs currently queued for `id` (0 after unregister; test hook).
    pub fn queued(&self, id: ClientId) -> usize {
        let g = self.state();
        g.clients.get(&id.0).map(|q| q.jobs.len()).unwrap_or(0)
    }

    /// Deficit round-robin: the front-of-round client earns `quantum`
    /// when it cannot yet afford its head job, dispatches while its
    /// deficit covers the head's cost, and rotates to the back when its
    /// allowance is spent.  An emptied queue leaves the round and
    /// forfeits its deficit (standard DRR — idle clients must not bank
    /// credit).
    fn pop_drr(
        cfg: &FairnessConfig,
        g: &mut State<T>,
        blocked: &[ClientId],
    ) -> Option<(ClientId, T)> {
        for _ in 0..g.order.len() {
            let Some(&cid) = g.order.front() else { break };
            if blocked.contains(&ClientId(cid)) {
                g.order.rotate_left(1);
                continue;
            }
            // The round only holds live clients with non-empty queues
            // (every mutation maintains this under the lock), so the
            // two `else` arms below are unreachable; if the invariant
            // ever broke, the stale entry heals by leaving the round
            // instead of panicking the scheduler thread.
            let Some(q) = g.clients.get_mut(&cid) else {
                g.order.pop_front();
                continue;
            };
            let Some(head_cost) = q.jobs.front().map(|j| j.1) else {
                g.order.pop_front();
                continue;
            };
            if q.deficit < head_cost {
                q.deficit += cfg.quantum;
            }
            if q.deficit < head_cost {
                // Still saving up for an expensive job: next client.
                g.order.rotate_left(1);
                continue;
            }
            let Some((_seq, cost, job)) = q.jobs.pop_front() else {
                g.order.pop_front();
                continue;
            };
            q.deficit -= cost;
            q.passes = 0;
            q.counters.record_dispatched();
            match q.jobs.front().map(|j| j.1) {
                None => {
                    q.deficit = 0;
                    g.order.pop_front();
                }
                Some(next_cost) if q.deficit < next_cost => {
                    // Allowance spent for this round: yield the front.
                    // (It keeps the remainder but earns its next quantum
                    // only when the round comes back around.)
                    g.order.rotate_left(1);
                }
                Some(_) => {}
            }
            Self::charge_passes(cfg, g, cid, blocked);
            return Some((ClientId(cid), job));
        }
        None
    }

    /// Global arrival order: dispatch the oldest queued job over all
    /// unblocked clients (the pre-fairness behavior, kept as the
    /// measurable control).
    fn pop_fifo(
        cfg: &FairnessConfig,
        g: &mut State<T>,
        blocked: &[ClientId],
    ) -> Option<(ClientId, T)> {
        // Runnable clients are live with non-empty queues by invariant;
        // `filter_map`/`?` make a broken entry skip or bail gracefully
        // instead of panicking the scheduler thread.
        let oldest = g
            .order
            .iter()
            .filter(|&&c| !blocked.contains(&ClientId(c)))
            .filter_map(|&c| {
                let head_seq = g.clients.get(&c)?.jobs.front()?.0;
                Some((head_seq, c))
            })
            .min()?
            .1;
        let q = g.clients.get_mut(&oldest)?;
        let (_seq, _cost, job) = q.jobs.pop_front()?;
        q.passes = 0;
        q.counters.record_dispatched();
        if q.jobs.is_empty() {
            q.deficit = 0;
            g.order.retain(|&c| c != oldest);
        }
        Self::charge_passes(cfg, g, oldest, blocked);
        Some((ClientId(oldest), job))
    }

    /// Starvation accounting: the dispatch that just served `winner`
    /// charges one pass to every other runnable, unblocked client; a
    /// client passed `max(16, 4 × runnable × quantum)` times in a row
    /// records a starvation event and resets.  DRR's per-round service
    /// guarantee keeps every client far below the threshold.
    ///
    /// This walk is O(runnable clients) per dispatch — a few u64 bumps
    /// per backlogged connection, dwarfed by the engine work each
    /// dispatch buys at today's connection counts.  If the front-end
    /// ever schedules tens of thousands of concurrently backlogged
    /// clients, replace it with a global dispatch sequence number plus
    /// per-client last-served marks, computing passes lazily.
    fn charge_passes(cfg: &FairnessConfig, g: &mut State<T>, winner: u64, blocked: &[ClientId]) {
        let runnable = g.order.len() as u64;
        let threshold = (4 * runnable.max(1) * cfg.quantum).max(16);
        let State { order, clients, .. } = g;
        for &cid in order.iter() {
            if cid == winner || blocked.contains(&ClientId(cid)) {
                continue;
            }
            // Invariant as in `pop_drr`: round entries are live; skip a
            // broken one rather than panic mid-dispatch.
            let Some(q) = clients.get_mut(&cid) else { continue };
            q.passes += 1;
            if q.passes >= threshold {
                q.counters.record_starved();
                q.passes = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(policy: FairnessPolicy, quantum: u64, cap: usize) -> FairScheduler<u32> {
        FairScheduler::new(FairnessConfig { policy, quantum, client_queue: cap })
    }

    fn counters() -> Arc<ClientCounters> {
        Arc::new(ClientCounters::default())
    }

    fn drain(s: &FairScheduler<u32>, n: usize) -> Vec<(ClientId, u32)> {
        (0..n)
            .map(|_| match s.next(&[], Duration::from_secs(5)) {
                Next::Job(id, j) => (id, j),
                Next::TimedOut => panic!("scheduler timed out with work queued"),
                Next::Stopped => panic!("scheduler stopped mid-test"),
            })
            .collect()
    }

    #[test]
    fn drr_round_robins_backlogged_clients() {
        let s = sched(FairnessPolicy::Drr, 1, 64);
        let (ca, cb) = (counters(), counters());
        let a = s.register(Arc::clone(&ca));
        let b = s.register(Arc::clone(&cb));
        for i in 0..6 {
            s.enqueue(a, 1, 100 + i).unwrap();
        }
        for i in 0..6 {
            s.enqueue(b, 1, 200 + i).unwrap();
        }
        let got = drain(&s, 12);
        // Strict alternation: neither backlog ever gets two in a row.
        for w in got.windows(2) {
            assert_ne!(w[0].0, w[1].0, "DRR with unit costs must alternate: {got:?}");
        }
        // Per-client FIFO order is preserved.
        let a_jobs: Vec<u32> = got.iter().filter(|(id, _)| *id == a).map(|&(_, j)| j).collect();
        assert_eq!(a_jobs, vec![100, 101, 102, 103, 104, 105]);
        assert_eq!(ca.dispatched(), 6);
        assert_eq!(cb.dispatched(), 6);
        assert_eq!(ca.starved() + cb.starved(), 0, "DRR never starves");
    }

    #[test]
    fn fifo_serves_arrival_order_and_records_starvation() {
        let s = sched(FairnessPolicy::Fifo, 1, 1024);
        let (ca, cb) = (counters(), counters());
        let a = s.register(Arc::clone(&ca));
        let b = s.register(Arc::clone(&cb));
        for i in 0..100u32 {
            s.enqueue(a, 1, i).unwrap();
        }
        s.enqueue(b, 1, 999).unwrap();
        let got = drain(&s, 101);
        // FIFO: the hog's entire backlog goes first.
        assert!(got[..100].iter().all(|(id, _)| *id == a));
        assert_eq!(got[100], (b, 999));
        assert!(
            cb.starved() >= 4,
            "100 passes at threshold 16 must record starvation (got {})",
            cb.starved()
        );
        assert_eq!(ca.starved(), 0);

        // The same shape under DRR: the late polite client is served
        // second overall, and nobody starves.
        let s = sched(FairnessPolicy::Drr, 1, 1024);
        let (ca, cb) = (counters(), counters());
        let a = s.register(Arc::clone(&ca));
        let b = s.register(Arc::clone(&cb));
        for i in 0..100u32 {
            s.enqueue(a, 1, i).unwrap();
        }
        s.enqueue(b, 1, 999).unwrap();
        let got = drain(&s, 101);
        let b_pos = got.iter().position(|(id, _)| *id == b).unwrap();
        assert!(b_pos <= 1, "DRR serves the polite client within one round, got {b_pos}");
        assert_eq!(ca.starved() + cb.starved(), 0);
    }

    #[test]
    fn drr_deficit_shares_by_cost_not_request_count() {
        // A's jobs cost 3, B's cost 1, quantum 1: bandwidth-fair service
        // dispatches three B jobs per A job.
        let s = sched(FairnessPolicy::Drr, 1, 64);
        let a = s.register(counters());
        let b = s.register(counters());
        for i in 0..3 {
            s.enqueue(a, 3, 100 + i).unwrap();
        }
        for i in 0..9 {
            s.enqueue(b, 1, 200 + i).unwrap();
        }
        let got = drain(&s, 12);
        let a_count = got.iter().filter(|(id, _)| *id == a).count();
        assert_eq!(a_count, 3, "all of A's jobs dispatch: {got:?}");
        // In every prefix, B's dispatched *cost* stays within one
        // quantum-round of A's (3 B-units per A job): A never lags more
        // than one expensive job behind its fair share.
        let mut a_cost = 0i64;
        let mut b_cost = 0i64;
        for (id, _) in &got {
            if *id == a {
                a_cost += 3;
            } else {
                b_cost += 1;
            }
            assert!(
                (a_cost - b_cost).abs() <= 4,
                "cost shares diverged: a={a_cost} b={b_cost} in {got:?}"
            );
        }
    }

    #[test]
    fn blocked_clients_are_skipped_without_losing_their_turn() {
        let s = sched(FairnessPolicy::Drr, 1, 64);
        let a = s.register(counters());
        let b = s.register(counters());
        s.enqueue(a, 1, 1).unwrap();
        s.enqueue(a, 1, 2).unwrap();
        s.enqueue(b, 1, 3).unwrap();
        // With A blocked, only B's work is dispatchable.
        match s.next(&[a], Duration::from_millis(50)) {
            Next::Job(id, 3) => assert_eq!(id, b),
            _ => panic!("expected B's job"),
        }
        // Nothing else is dispatchable while A stays blocked.
        assert!(matches!(s.next(&[a], Duration::from_millis(20)), Next::TimedOut));
        // Unblocked, A's queue drains in order.
        let got = drain(&s, 2);
        assert_eq!(got, vec![(a, 1), (a, 2)]);
    }

    #[test]
    fn enqueue_blocks_at_capacity_until_a_pop_frees_space() {
        let s = sched(FairnessPolicy::Drr, 1, 2);
        let a = s.register(counters());
        s.enqueue(a, 1, 1).unwrap();
        s.enqueue(a, 1, 2).unwrap();
        let s2 = s.clone();
        let blocked_enqueue = std::thread::spawn(move || s2.enqueue(a, 1, 3));
        // Give the thread time to hit the full queue, then pop: the
        // blocked enqueue must complete.
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(s.queued(a), 2, "third enqueue must be parked, not queued");
        let _ = drain(&s, 1);
        blocked_enqueue.join().unwrap().unwrap();
        assert_eq!(s.queued(a), 2);
        let got = drain(&s, 2);
        assert_eq!(got, vec![(a, 2), (a, 3)]);
    }

    #[test]
    fn unregister_drops_jobs_and_wakes_blocked_enqueuers() {
        let s = sched(FairnessPolicy::Drr, 1, 1);
        let a = s.register(counters());
        let b = s.register(counters());
        s.enqueue(a, 1, 1).unwrap();
        let s2 = s.clone();
        let waiter = std::thread::spawn(move || s2.enqueue(a, 1, 2));
        std::thread::sleep(Duration::from_millis(30));
        s.unregister(a);
        assert_eq!(waiter.join().unwrap(), Err(Closed), "blocked enqueue observes removal");
        assert_eq!(s.queued(a), 0, "unregister drops the queue");
        assert!(s.enqueue(a, 1, 3).is_err(), "a removed client cannot enqueue");
        // The scheduler keeps serving other clients.
        s.enqueue(b, 1, 9).unwrap();
        let got = drain(&s, 1);
        assert_eq!(got, vec![(b, 9)]);
    }

    #[test]
    fn stop_wakes_next_and_enqueue() {
        let s = sched(FairnessPolicy::Drr, 1, 1);
        let a = s.register(counters());
        s.enqueue(a, 1, 1).unwrap(); // fills the cap-1 queue
        let s2 = s.clone();
        // Blocking `a` keeps the queue full, so `next` waits and the
        // second enqueue below parks — both must be woken by stop().
        let next_thread = std::thread::spawn(move || {
            matches!(s2.next(&[a], Duration::from_secs(5)), Next::Stopped)
        });
        let s3 = s.clone();
        let enqueue_thread = std::thread::spawn(move || s3.enqueue(a, 1, 2));
        std::thread::sleep(Duration::from_millis(50));
        s.stop();
        assert!(next_thread.join().unwrap(), "next must observe Stopped");
        assert_eq!(enqueue_thread.join().unwrap(), Err(Closed));
        assert!(matches!(s.next(&[], Duration::from_millis(1)), Next::Stopped));
    }
}
