//! Versioned length-prefixed binary wire protocol of the L4 front-end.
//!
//! Every frame is `[u32 body-length (LE)] [body]`; the body starts with a
//! version byte and a kind byte, so the protocol can evolve without
//! breaking framing.  All integers are little-endian; logits travel as
//! raw IEEE-754 f32 bits, so network scores are bit-identical to
//! in-process scores.
//!
//! ```text
//! request  body: [ver u8][kind=1][id u64][arch u16+bytes][mode u16+bytes]
//!                [row u32+bytes]
//! swap     body: [ver u8][kind=3][id u64][arch u16+bytes][mode u16+bytes]
//!                [seed u64]
//! hello    body: [ver u8][kind=4][id u64][name u16+bytes]
//! stats    body: [ver u8][kind=5][id u64][reset u8]
//! response body: [ver u8][kind=2][id u64][status u8] ...
//!   status 0 Ok:             [shard u32][argmax u8][cached u8][epoch u64]
//!                            [10 x f32]
//!   status 1 Error:          [kind u8][message u32+bytes]
//!   status 2 Overloaded:     [retry_after_ms u32]
//!   status 3 Swapped:        [epoch u64]
//!   status 4 TooManyConns:   [retry_after_ms u32]
//!   status 5 Stats:          [json u32+bytes]
//! ```
//!
//! Version 2 added the weights *epoch* to `Ok` (which generation of the
//! model produced the scores) and the swap surface (`kind 3` requests a
//! hot weight swap; `Swapped` acknowledges it with the new epoch) — the
//! `Ok` layout changed, hence the version bump.
//!
//! Version 3 added connection governance: the `Hello` frame (kind 4) —
//! an optional, fire-and-forget self-identification a client may send
//! before its first request so the server's per-client fairness metrics
//! carry a human-chosen name instead of a generated `conn-N` — and the
//! `TooManyConnections` status (4), written once (with id 0) to a
//! connection refused by the server's connection cap before it is
//! closed, so conn-limit rejection is *typed* on the wire rather than a
//! silent drop.
//!
//! Version 4 added the observability surface: the `Stats` frame (kind
//! 5) asks a live server for its current `MetricsReport` — per-stage
//! latency summaries included — without disturbing serving; the
//! matching `Stats` status (5) carries the report back as a JSON string
//! (the same document `serve --metrics-json` writes).  `reset` drains
//! the per-stage summaries after the snapshot, so a scraper (e.g.
//! `odin loadgen`) can attribute stage latencies to its own window.
//!
//! Decoding is strict: unknown versions, kinds, status/error codes,
//! truncated bodies, trailing bytes, and frame lengths outside
//! `1..=`[`MAX_FRAME`] are all `InvalidData` errors — a malformed or
//! hostile peer can never make the server allocate unboundedly or
//! misparse a frame.  Exhaustive encode/decode round-trip tests live at
//! the bottom of this module.

use std::io::{self, Read, Write};

/// Protocol version byte carried by every frame.
pub const WIRE_VERSION: u8 = 4;

/// Upper bound on a frame body, guarding malformed/hostile length
/// prefixes (a 784-byte MNIST row frame is ~850 bytes).
pub const MAX_FRAME: usize = 1 << 20;

const KIND_REQUEST: u8 = 1;
const KIND_RESPONSE: u8 = 2;
const KIND_SWAP: u8 = 3;
const KIND_HELLO: u8 = 4;
const KIND_STATS: u8 = 5;

/// Response status discriminants (the byte after the response id).
/// Named so the encode arm, decode arm, and round-trip test for each
/// variant share one definition — the `wire-coverage` lint keeps all
/// three sites in sync.
const STATUS_OK: u8 = 0;
const STATUS_ERROR: u8 = 1;
const STATUS_OVERLOADED: u8 = 2;
const STATUS_SWAPPED: u8 = 3;
const STATUS_TOO_MANY_CONNS: u8 = 4;
const STATUS_STATS: u8 = 5;

/// Typed error kinds a response can carry — the wire mirror of
/// [`crate::coordinator::ServeError`] plus protocol-level rejections.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireErrorKind {
    /// The request frame itself was malformed or misused the protocol.
    BadRequest,
    /// The row payload has the wrong byte width for the served model.
    WrongRowWidth,
    /// The requested arch/mode is not what this front-end serves.
    UnknownModel,
    /// The backend failed while executing the request's batch.
    Backend,
    /// The server stopped before answering.
    Shutdown,
}

impl WireErrorKind {
    fn code(self) -> u8 {
        match self {
            WireErrorKind::BadRequest => 0,
            WireErrorKind::WrongRowWidth => 1,
            WireErrorKind::UnknownModel => 2,
            WireErrorKind::Backend => 3,
            WireErrorKind::Shutdown => 4,
        }
    }

    fn from_code(c: u8) -> Option<Self> {
        match c {
            0 => Some(WireErrorKind::BadRequest),
            1 => Some(WireErrorKind::WrongRowWidth),
            2 => Some(WireErrorKind::UnknownModel),
            3 => Some(WireErrorKind::Backend),
            4 => Some(WireErrorKind::Shutdown),
            _ => None,
        }
    }
}

/// One inference request: client-chosen correlation id, the model
/// coordinates, and the raw input row.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireRequest {
    /// Client-chosen id echoed back in the response (pipelining key).
    pub id: u64,
    /// Topology name ("cnn1", "cnn2", ...).
    pub arch: String,
    /// Arithmetic mode ("fast", "sc", "mux", "float").
    pub mode: String,
    /// Raw input row bytes (784 for the benchmark CNNs).
    pub row: Vec<u8>,
}

/// One hot-swap request: install a new weight generation for a served
/// model.  The server reloads from the model's weight source (real
/// artifacts when present, deterministic synthetic weights from `seed`
/// otherwise) and answers [`WireStatus::Swapped`] with the new epoch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireSwap {
    /// Client-chosen id echoed back in the response.
    pub id: u64,
    /// Topology name of the model to swap.
    pub arch: String,
    /// Arithmetic mode of the model to swap.
    pub mode: String,
    /// Seed for the synthetic-weights fallback of the reload.
    pub seed: u64,
}

/// One client self-identification: an optional fire-and-forget frame a
/// client may send before its first request so the server's per-client
/// fairness accounting (queue share, starvation counters, the metrics
/// JSON) reports a client-chosen name.  The server sends no reply; a
/// `Hello` after the connection's fairness slot exists (i.e. after its
/// first pool-bound request) is ignored.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireHello {
    /// Client-chosen id (unused — `Hello` gets no response — but kept
    /// so every frame shares the id-first layout).
    pub id: u64,
    /// The client's self-chosen display name.  Arbitrary UTF-8: the
    /// metrics JSON emitter escapes control characters, which the
    /// loopback tests pin end to end.
    pub name: String,
}

/// One live-stats request: ask the server for its current metrics
/// report (answered with [`WireStatus::Stats`]).  With `reset` set, the
/// server drains its per-stage latency summaries after the snapshot so
/// the next scrape covers only the window since this one — how
/// `odin loadgen` gets true per-scenario stage breakdowns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireStats {
    /// Client-chosen id echoed back in the response.
    pub id: u64,
    /// Drain the per-stage summaries after snapshotting.
    pub reset: bool,
}

/// Response payload: scores, a typed error, an overload rejection, a
/// swap acknowledgement, or a stats report.
#[derive(Clone, Debug, PartialEq)]
pub enum WireStatus {
    /// Successful inference.
    Ok {
        /// Pool shard that executed (or originally produced, for cache
        /// hits) this result.
        shard: u32,
        /// Predicted class (index of the largest logit).
        argmax: u8,
        /// True when served from the response cache without pool work.
        cached: bool,
        /// Weights epoch that produced these scores (cache hits replay
        /// the epoch that originally executed the row).
        epoch: u64,
        /// Raw per-class logits, bit-identical to in-process execution.
        logits: [f32; 10],
    },
    /// Typed failure; the request was seen but could not be served.
    Error {
        /// What went wrong.
        kind: WireErrorKind,
        /// Human-readable detail.
        message: String,
    },
    /// Shed by admission control; retry after the hinted backoff.
    Overloaded {
        /// Suggested client backoff before retrying (milliseconds).
        retry_after_ms: u32,
    },
    /// A hot weight swap was installed; later responses for the model
    /// report this (or a newer) epoch.
    Swapped {
        /// The newly installed weights epoch.
        epoch: u64,
    },
    /// The server's connection cap is reached: this connection was
    /// refused.  Written once with id 0, then the server closes the
    /// socket — reconnect after the hinted backoff.
    TooManyConnections {
        /// Suggested client backoff before reconnecting (milliseconds).
        retry_after_ms: u32,
    },
    /// The server's live metrics report (the answer to a
    /// [`WireStats`] request).
    Stats {
        /// The `MetricsReport` as a JSON document — the same shape
        /// `serve --metrics-json` writes, per-stage summaries included.
        json: String,
    },
}

/// One response frame (the echo of a request id plus its status).
#[derive(Clone, Debug, PartialEq)]
pub struct WireResponse {
    /// The id of the request this answers.
    pub id: u64,
    /// Outcome.
    pub status: WireStatus,
}

/// A decoded frame: either direction of the protocol.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Client-to-server inference request.
    Request(WireRequest),
    /// Server-to-client response.
    Response(WireResponse),
    /// Client-to-server hot-swap request (answered with
    /// [`WireStatus::Swapped`] or a typed error).
    Swap(WireSwap),
    /// Client-to-server self-identification (fire and forget).
    Hello(WireHello),
    /// Client-to-server live-stats request (answered with
    /// [`WireStatus::Stats`]).
    Stats(WireStats),
}

fn bad(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian reader over a frame body.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cursor { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.b.len() - self.i < n {
            return Err(bad(format!(
                "truncated frame: wanted {n} bytes at offset {}, have {}",
                self.i,
                self.b.len() - self.i
            )));
        }
        // panic-ok: the length check above guarantees `i + n <= b.len()`.
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        // panic-ok: `take(1)` returns exactly one byte or errors.
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> io::Result<u16> {
        // panic-ok: `take(2)` returns exactly 2 bytes, so the array
        // conversion is infallible.
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> io::Result<u32> {
        // panic-ok: `take(4)` returns exactly 4 bytes (see `u16`).
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        // panic-ok: `take(8)` returns exactly 8 bytes (see `u16`).
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> io::Result<f32> {
        // panic-ok: `take(4)` returns exactly 4 bytes (see `u16`).
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn string(&mut self, len: usize) -> io::Result<String> {
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| bad("non-utf8 string field".to_string()))
    }

    fn finish(&self) -> io::Result<()> {
        if self.i != self.b.len() {
            return Err(bad(format!("{} trailing bytes after frame body", self.b.len() - self.i)));
        }
        Ok(())
    }
}

impl Frame {
    /// Encode the full frame, length prefix included.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::with_capacity(64);
        body.push(WIRE_VERSION);
        match self {
            Frame::Request(r) => {
                body.push(KIND_REQUEST);
                put_u64(&mut body, r.id);
                put_u16(&mut body, r.arch.len() as u16);
                body.extend_from_slice(r.arch.as_bytes());
                put_u16(&mut body, r.mode.len() as u16);
                body.extend_from_slice(r.mode.as_bytes());
                put_u32(&mut body, r.row.len() as u32);
                body.extend_from_slice(&r.row);
            }
            Frame::Response(r) => {
                body.push(KIND_RESPONSE);
                put_u64(&mut body, r.id);
                match &r.status {
                    WireStatus::Ok { shard, argmax, cached, epoch, logits } => {
                        body.push(STATUS_OK);
                        put_u32(&mut body, *shard);
                        body.push(*argmax);
                        body.push(u8::from(*cached));
                        put_u64(&mut body, *epoch);
                        for l in logits {
                            body.extend_from_slice(&l.to_le_bytes());
                        }
                    }
                    WireStatus::Error { kind, message } => {
                        body.push(STATUS_ERROR);
                        body.push(kind.code());
                        put_u32(&mut body, message.len() as u32);
                        body.extend_from_slice(message.as_bytes());
                    }
                    WireStatus::Overloaded { retry_after_ms } => {
                        body.push(STATUS_OVERLOADED);
                        put_u32(&mut body, *retry_after_ms);
                    }
                    WireStatus::Swapped { epoch } => {
                        body.push(STATUS_SWAPPED);
                        put_u64(&mut body, *epoch);
                    }
                    WireStatus::TooManyConnections { retry_after_ms } => {
                        body.push(STATUS_TOO_MANY_CONNS);
                        put_u32(&mut body, *retry_after_ms);
                    }
                    WireStatus::Stats { json } => {
                        body.push(STATUS_STATS);
                        put_u32(&mut body, json.len() as u32);
                        body.extend_from_slice(json.as_bytes());
                    }
                }
            }
            Frame::Swap(s) => {
                body.push(KIND_SWAP);
                put_u64(&mut body, s.id);
                put_u16(&mut body, s.arch.len() as u16);
                body.extend_from_slice(s.arch.as_bytes());
                put_u16(&mut body, s.mode.len() as u16);
                body.extend_from_slice(s.mode.as_bytes());
                put_u64(&mut body, s.seed);
            }
            Frame::Hello(h) => {
                body.push(KIND_HELLO);
                put_u64(&mut body, h.id);
                put_u16(&mut body, h.name.len() as u16);
                body.extend_from_slice(h.name.as_bytes());
            }
            Frame::Stats(s) => {
                body.push(KIND_STATS);
                put_u64(&mut body, s.id);
                body.push(u8::from(s.reset));
            }
        }
        // Oversized bodies are rejected by `write_frame` (and by the
        // peer's `read_frame`); encode itself stays total.
        let mut out = Vec::with_capacity(4 + body.len());
        put_u32(&mut out, body.len() as u32);
        out.extend_from_slice(&body);
        out
    }

    /// Decode a frame body (the bytes after the length prefix).
    pub fn decode_body(body: &[u8]) -> io::Result<Frame> {
        let mut c = Cursor::new(body);
        let version = c.u8()?;
        if version != WIRE_VERSION {
            return Err(bad(format!("unsupported wire version {version} (want {WIRE_VERSION})")));
        }
        let kind = c.u8()?;
        let frame = match kind {
            KIND_REQUEST => {
                let id = c.u64()?;
                let arch_len = c.u16()? as usize;
                let arch = c.string(arch_len)?;
                let mode_len = c.u16()? as usize;
                let mode = c.string(mode_len)?;
                let row_len = c.u32()? as usize;
                let row = c.take(row_len)?.to_vec();
                Frame::Request(WireRequest { id, arch, mode, row })
            }
            KIND_RESPONSE => {
                let id = c.u64()?;
                let status = match c.u8()? {
                    STATUS_OK => {
                        let shard = c.u32()?;
                        let argmax = c.u8()?;
                        let cached = c.u8()? != 0;
                        let epoch = c.u64()?;
                        let mut logits = [0f32; 10];
                        for l in logits.iter_mut() {
                            *l = c.f32()?;
                        }
                        WireStatus::Ok { shard, argmax, cached, epoch, logits }
                    }
                    STATUS_ERROR => {
                        let code = c.u8()?;
                        let kind = WireErrorKind::from_code(code)
                            .ok_or_else(|| bad(format!("unknown error kind {code}")))?;
                        let msg_len = c.u32()? as usize;
                        let message = c.string(msg_len)?;
                        WireStatus::Error { kind, message }
                    }
                    STATUS_OVERLOADED => WireStatus::Overloaded { retry_after_ms: c.u32()? },
                    STATUS_SWAPPED => WireStatus::Swapped { epoch: c.u64()? },
                    STATUS_TOO_MANY_CONNS => {
                        WireStatus::TooManyConnections { retry_after_ms: c.u32()? }
                    }
                    STATUS_STATS => {
                        let json_len = c.u32()? as usize;
                        WireStatus::Stats { json: c.string(json_len)? }
                    }
                    s => return Err(bad(format!("unknown response status {s}"))),
                };
                Frame::Response(WireResponse { id, status })
            }
            KIND_SWAP => {
                let id = c.u64()?;
                let arch_len = c.u16()? as usize;
                let arch = c.string(arch_len)?;
                let mode_len = c.u16()? as usize;
                let mode = c.string(mode_len)?;
                let seed = c.u64()?;
                Frame::Swap(WireSwap { id, arch, mode, seed })
            }
            KIND_HELLO => {
                let id = c.u64()?;
                let name_len = c.u16()? as usize;
                let name = c.string(name_len)?;
                Frame::Hello(WireHello { id, name })
            }
            KIND_STATS => {
                let id = c.u64()?;
                let reset = c.u8()? != 0;
                Frame::Stats(WireStats { id, reset })
            }
            k => return Err(bad(format!("unknown frame kind {k}"))),
        };
        c.finish()?;
        Ok(frame)
    }
}

/// Write one frame and flush it onto the wire.  A frame whose body
/// exceeds [`MAX_FRAME`] is rejected *before* any byte is written — the
/// peer would refuse it at the length prefix and kill the connection, so
/// failing locally keeps the stream clean and the connection alive.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> io::Result<()> {
    let bytes = frame.encode();
    if bytes.len() - 4 > MAX_FRAME {
        return Err(bad(format!("frame body of {} bytes exceeds {MAX_FRAME}", bytes.len() - 4)));
    }
    w.write_all(&bytes)?;
    w.flush()
}

/// Read one frame.  Returns `Ok(None)` on a clean EOF at a frame
/// boundary; EOF mid-frame and every malformed encoding are errors.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Frame>> {
    let mut len4 = [0u8; 4];
    if !read_exact_or_eof(r, &mut len4)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(bad(format!("frame length {len} outside 1..={MAX_FRAME}")));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Frame::decode_body(&body).map(Some)
}

/// `read_exact` that distinguishes a clean EOF before the first byte
/// (`Ok(false)`) from EOF mid-buffer (an error).
fn read_exact_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> io::Result<bool> {
    let mut n = 0;
    while n < buf.len() {
        // panic-ok: `n < buf.len()` (loop guard) keeps the range valid.
        match r.read(&mut buf[n..]) {
            Ok(0) => {
                if n == 0 {
                    return Ok(false);
                }
                return Err(bad("eof mid-frame".to_string()));
            }
            Ok(k) => n += k,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(frame: Frame) {
        let bytes = frame.encode();
        let mut r = bytes.as_slice();
        let decoded = read_frame(&mut r).unwrap().expect("a frame");
        assert_eq!(decoded, frame);
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF after one frame");
    }

    #[test]
    fn request_round_trips() {
        round_trip(Frame::Request(WireRequest {
            id: 0,
            arch: String::new(),
            mode: String::new(),
            row: Vec::new(),
        }));
        round_trip(Frame::Request(WireRequest {
            id: u64::MAX,
            arch: "cnn1".to_string(),
            mode: "fast".to_string(),
            row: (0..=255).cycle().take(784).collect(),
        }));
    }

    #[test]
    fn every_response_status_round_trips() {
        let logits = [
            0.0f32,
            -1.5,
            f32::MIN_POSITIVE,
            f32::MAX,
            f32::MIN,
            1e-30,
            3.25,
            -0.0,
            42.0,
            7.125,
        ];
        round_trip(Frame::Response(WireResponse {
            id: 7,
            status: WireStatus::Ok { shard: 3, argmax: 9, cached: true, epoch: 0, logits },
        }));
        round_trip(Frame::Response(WireResponse {
            id: 8,
            status: WireStatus::Ok {
                shard: u32::MAX,
                argmax: 0,
                cached: false,
                epoch: u64::MAX,
                logits,
            },
        }));
        for kind in [
            WireErrorKind::BadRequest,
            WireErrorKind::WrongRowWidth,
            WireErrorKind::UnknownModel,
            WireErrorKind::Backend,
            WireErrorKind::Shutdown,
        ] {
            round_trip(Frame::Response(WireResponse {
                id: 9,
                status: WireStatus::Error { kind, message: format!("boom {kind:?}") },
            }));
        }
        round_trip(Frame::Response(WireResponse {
            id: 10,
            status: WireStatus::Overloaded { retry_after_ms: 25 },
        }));
        round_trip(Frame::Response(WireResponse {
            id: 11,
            status: WireStatus::Swapped { epoch: 3 },
        }));
        round_trip(Frame::Response(WireResponse {
            id: 0,
            status: WireStatus::TooManyConnections { retry_after_ms: 50 },
        }));
    }

    #[test]
    fn hello_frames_round_trip_including_control_characters() {
        round_trip(Frame::Hello(WireHello { id: 0, name: String::new() }));
        // Client names are arbitrary UTF-8 — control characters and
        // non-ASCII must survive the wire untouched (the metrics JSON
        // emitter, not the wire, is responsible for escaping them).
        round_trip(Frame::Hello(WireHello {
            id: 42,
            name: "alice\u{1}\t\n\"\\Ω馬".to_string(),
        }));
        // Truncation strictness holds for the hello layout too.
        let full = Frame::Hello(WireHello { id: 3, name: "bob".to_string() }).encode();
        let body = &full[4..];
        for cut in 0..body.len() {
            assert!(Frame::decode_body(&body[..cut]).is_err(), "prefix {cut} decoded");
        }
    }

    #[test]
    fn swap_frames_round_trip() {
        round_trip(Frame::Swap(WireSwap {
            id: 0,
            arch: String::new(),
            mode: String::new(),
            seed: 0,
        }));
        round_trip(Frame::Swap(WireSwap {
            id: u64::MAX,
            arch: "cnn1".to_string(),
            mode: "fast".to_string(),
            seed: 0xDEAD_BEEF,
        }));
        // Truncation strictness holds for the swap layout too.
        let full = Frame::Swap(WireSwap {
            id: 3,
            arch: "cnn2".to_string(),
            mode: "sc".to_string(),
            seed: 42,
        })
        .encode();
        let body = &full[4..];
        for cut in 0..body.len() {
            assert!(Frame::decode_body(&body[..cut]).is_err(), "prefix {cut} decoded");
        }
    }

    #[test]
    fn stats_frames_round_trip() {
        round_trip(Frame::Stats(WireStats { id: 0, reset: false }));
        round_trip(Frame::Stats(WireStats { id: u64::MAX, reset: true }));
        // The stats *response* carries an arbitrary JSON string,
        // non-ASCII included (model names key the report).
        round_trip(Frame::Response(WireResponse {
            id: 12,
            status: WireStatus::Stats { json: String::new() },
        }));
        round_trip(Frame::Response(WireResponse {
            id: 13,
            status: WireStatus::Stats {
                json: "{\"requests\":42,\"models\":[{\"model\":\"モデル/fast\"}]}".to_string(),
            },
        }));
        // Truncation strictness holds for both new layouts.
        for frame in [
            Frame::Stats(WireStats { id: 3, reset: true }),
            Frame::Response(WireResponse {
                id: 4,
                status: WireStatus::Stats { json: "{\"requests\":1}".to_string() },
            }),
        ] {
            let full = frame.encode();
            let body = &full[4..];
            for cut in 0..body.len() {
                assert!(Frame::decode_body(&body[..cut]).is_err(), "prefix {cut} decoded");
            }
        }
    }

    #[test]
    fn pipelined_frames_decode_in_order() {
        let mut bytes = Vec::new();
        for id in 0..5u64 {
            bytes.extend_from_slice(
                &Frame::Request(WireRequest {
                    id,
                    arch: "cnn1".to_string(),
                    mode: "fast".to_string(),
                    row: vec![id as u8; 16],
                })
                .encode(),
            );
        }
        let mut r = bytes.as_slice();
        for id in 0..5u64 {
            match read_frame(&mut r).unwrap().unwrap() {
                Frame::Request(req) => assert_eq!(req.id, id),
                f => panic!("unexpected frame {f:?}"),
            }
        }
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn rejects_wrong_version() {
        let mut bytes = Frame::Request(WireRequest {
            id: 1,
            arch: "cnn1".to_string(),
            mode: "fast".to_string(),
            row: vec![0; 4],
        })
        .encode();
        bytes[4] = WIRE_VERSION + 1; // version byte is first in the body
        assert!(read_frame(&mut bytes.as_slice()).is_err());
    }

    #[test]
    fn rejects_unknown_kind_status_and_error_code() {
        assert!(Frame::decode_body(&[WIRE_VERSION, 9]).is_err(), "unknown kind");
        // response with unknown status byte
        let mut body = vec![WIRE_VERSION, 2];
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(9);
        assert!(Frame::decode_body(&body).is_err(), "unknown status");
        // error status with unknown error code
        let mut body = vec![WIRE_VERSION, 2];
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(1);
        body.push(99);
        body.extend_from_slice(&0u32.to_le_bytes());
        assert!(Frame::decode_body(&body).is_err(), "unknown error kind");
    }

    #[test]
    fn rejects_truncation_and_trailing_bytes() {
        let full = Frame::Request(WireRequest {
            id: 3,
            arch: "cnn1".to_string(),
            mode: "fast".to_string(),
            row: vec![1, 2, 3],
        })
        .encode();
        let body = &full[4..];
        // every strict prefix of the body must fail to decode
        for cut in 0..body.len() {
            assert!(Frame::decode_body(&body[..cut]).is_err(), "prefix {cut} decoded");
        }
        // trailing garbage after a valid body must fail too
        let mut extended = body.to_vec();
        extended.push(0);
        assert!(Frame::decode_body(&extended).is_err());
    }

    #[test]
    fn write_frame_rejects_oversized_body_without_writing() {
        let frame = Frame::Request(WireRequest {
            id: 1,
            arch: "cnn1".to_string(),
            mode: "fast".to_string(),
            row: vec![0u8; MAX_FRAME + 1],
        });
        let mut out = Vec::new();
        assert!(write_frame(&mut out, &frame).is_err());
        assert!(out.is_empty(), "nothing may reach the wire for an unframeable payload");
    }

    #[test]
    fn kind_and_status_bytes_are_pinned_to_their_constants() {
        // The on-wire discriminants are protocol surface: pin each
        // frame's kind byte (body offset 1, i.e. encoded offset 5) and
        // each response's status byte (encoded offset 14) to its named
        // constant, then round-trip the frame.  A renumbered constant
        // or a divergent encode/decode arm fails here.
        let kinds: [(Frame, u8); 5] = [
            (
                Frame::Request(WireRequest {
                    id: 1,
                    arch: "cnn1".to_string(),
                    mode: "fast".to_string(),
                    row: vec![7; 4],
                }),
                KIND_REQUEST,
            ),
            (
                Frame::Response(WireResponse {
                    id: 2,
                    status: WireStatus::Swapped { epoch: 1 },
                }),
                KIND_RESPONSE,
            ),
            (
                Frame::Swap(WireSwap {
                    id: 3,
                    arch: "cnn2".to_string(),
                    mode: "sc".to_string(),
                    seed: 9,
                }),
                KIND_SWAP,
            ),
            (Frame::Hello(WireHello { id: 4, name: "carol".to_string() }), KIND_HELLO),
            (Frame::Stats(WireStats { id: 5, reset: false }), KIND_STATS),
        ];
        for (frame, kind) in kinds {
            assert_eq!(frame.encode()[5], kind, "kind byte for {frame:?}");
            round_trip(frame);
        }
        let statuses: [(WireStatus, u8); 6] = [
            (
                WireStatus::Ok {
                    shard: 0,
                    argmax: 1,
                    cached: false,
                    epoch: 0,
                    logits: [0.5; 10],
                },
                STATUS_OK,
            ),
            (
                WireStatus::Error {
                    kind: WireErrorKind::Backend,
                    message: "x".to_string(),
                },
                STATUS_ERROR,
            ),
            (WireStatus::Overloaded { retry_after_ms: 1 }, STATUS_OVERLOADED),
            (WireStatus::Swapped { epoch: 2 }, STATUS_SWAPPED),
            (
                WireStatus::TooManyConnections { retry_after_ms: 3 },
                STATUS_TOO_MANY_CONNS,
            ),
            (WireStatus::Stats { json: "{}".to_string() }, STATUS_STATS),
        ];
        for (status, code) in statuses {
            let frame = Frame::Response(WireResponse { id: 9, status });
            let bytes = frame.encode();
            assert_eq!(bytes[5], KIND_RESPONSE);
            assert_eq!(bytes[14], code, "status byte for {frame:?}");
            round_trip(frame);
        }
    }

    #[test]
    fn rejects_hostile_lengths() {
        // frame length prefix of zero
        let zero = 0u32.to_le_bytes();
        assert!(read_frame(&mut zero.as_slice()).is_err());
        // frame length prefix beyond MAX_FRAME
        let huge = ((MAX_FRAME + 1) as u32).to_le_bytes();
        assert!(read_frame(&mut huge.as_slice()).is_err());
        // eof mid-frame (length says 100, only 3 bytes follow)
        let mut bytes = 100u32.to_le_bytes().to_vec();
        bytes.extend_from_slice(&[1, 2, 3]);
        assert!(read_frame(&mut bytes.as_slice()).is_err());
        // eof mid-length-prefix
        let short = [1u8, 0];
        assert!(read_frame(&mut short.as_slice()).is_err());
    }
}
