//! L4 network front-end: the TCP boundary that lets external clients
//! drive the sharded [`EnginePool`](crate::coordinator::EnginePool).
//!
//! ODIN's pitch is serving ANN inference at accelerator speed; the
//! ROADMAP's north star is a service under public traffic.  Layers 1-3
//! end at an in-process `Client`, so until now a request had to
//! originate inside the process that owns the pool.  This subsystem adds
//! the missing network boundary — std-only (no tokio, no serde: the
//! container is offline), mirroring how the rest of the stack owns its
//! substrates:
//!
//! * [`wire`] — versioned, length-prefixed binary protocol; strict
//!   decoding, exhaustive round-trip tests.  Version 2 carries the
//!   weights epoch on every `Ok` and a hot-swap surface
//!   (`Swap` → `Swapped{epoch}` / `UnknownModel`); version 4 adds the
//!   observability surface (`Stats` → `Stats{json}`), so a live server
//!   is scraped over the wire instead of killed for its report.
//! * [`framing`] — the one shared copy of the transport plumbing every
//!   wire speaker needs: length-prefixed frame I/O over a cloned-socket
//!   write half ([`framing::FramedConn`]), the write-timeout policy,
//!   wire-name validation, and the typed `TooManyConnections` refusal
//!   drain.  Server, client, and proxy all sit on this module, so the
//!   byte-level behaviors stay audited in exactly one place.
//! * [`server`] — `TcpListener` accept loop; per-connection reader and
//!   writer threads pipeline many in-flight requests per connection.
//!   [`ServeConfig`] is the one front-door builder: named knobs for
//!   cache, admission, fairness, connection caps, metrics, and tracing,
//!   with [`ServeConfig::serve_pool`] serving one `(arch, mode)` pool
//!   and [`ServeConfig::serve_registry`] routing per request across
//!   every model of a
//!   [`ModelRegistry`](crate::coordinator::ModelRegistry), honoring
//!   hot-swap frames.  Connections over the connection cap are refused
//!   with a typed `TooManyConnections{retry_after}` frame, never a
//!   silent drop.
//! * [`proxy`] — the L6 routing tier: `odin proxy` listens on the same
//!   wire protocol and fans requests out across N backend `odin serve`
//!   processes — hash or least-loaded routing over the healthy set,
//!   probe/eject/re-admit health tracking with typed drains, and
//!   fleet-wide `Swap` broadcast (an epoch is acknowledged only once
//!   every backend installed it).
//! * [`fairness`] — per-client fair queuing between the readers and the
//!   pool: every connection owns a bounded queue (a hog backpressures
//!   only itself) drained by one deficit-round-robin scheduler thread
//!   (`--fairness drr|fifo`), with per-client dispatch/starvation
//!   counters and a Jain fairness index in the metrics.
//! * [`admission`] — bounded in-flight gate with a `block` (TCP
//!   backpressure) or `shed` (structured `Overloaded{retry_after}`)
//!   policy, so overload never stalls the pool dispatcher.  The fair
//!   scheduler admits at dispatch time; cache hits bypass the gate
//!   entirely.
//! * [`cache`] — sharded LRU response cache keyed by the full
//!   `(arch, mode, epoch, row)` — bit-identical to uncached execution
//!   because every backend is deterministic per weight generation, and
//!   swap-safe because the epoch in the key makes pre-swap entries
//!   unreachable the moment new weights install.
//! * [`client`] — blocking and pipelining Rust clients used by the
//!   tests, `examples/mnist_serving.rs`, and
//!   `benches/net_throughput.rs`; [`NetClient::pipeline`] is the
//!   bounded-window async submit/reap pair (completion-order reaping,
//!   no head-of-line blocking), [`NetClient::swap`] drives wire-level
//!   hot swaps (`odin swap`), and [`NetClient::connect_named`] labels
//!   the connection's fairness counters.
//!
//! End to end: `odin serve --listen 127.0.0.1:0 --model cnn1:fast
//! --model cnn2:fast --cache 1024 --admission shed --queue-cap 256`
//! serves several models over loopback; everything stays hermetic and
//! offline.  See `docs/ARCHITECTURE.md` for the L4 design (wire format
//! table, admission state diagram, registry/epoch lifecycle).
#![deny(missing_docs)]

pub mod admission;
pub mod cache;
pub mod client;
pub mod fairness;
pub mod framing;
pub mod proxy;
pub mod server;
pub mod wire;

pub use admission::{AdmissionConfig, AdmissionGate, AdmissionPolicy, Permit};
pub use cache::{CacheKey, CachedScores, ResponseCache};
pub use client::{NetClient, NetError, NetResponse, Pipeline};
pub use fairness::{FairScheduler, FairnessConfig, FairnessPolicy};
pub use framing::FramedConn;
pub use proxy::{Proxy, ProxyConfig, RoutePolicy};
pub use server::{Frontend, FrontendConfig, ServeConfig};
pub use wire::{
    Frame, WireErrorKind, WireHello, WireRequest, WireResponse, WireStats, WireStatus, WireSwap,
    WIRE_VERSION,
};
