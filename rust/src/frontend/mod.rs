//! L4 network front-end: the TCP boundary that lets external clients
//! drive the sharded [`EnginePool`](crate::coordinator::EnginePool).
//!
//! ODIN's pitch is serving ANN inference at accelerator speed; the
//! ROADMAP's north star is a service under public traffic.  Layers 1-3
//! end at an in-process `Client`, so until now a request had to
//! originate inside the process that owns the pool.  This subsystem adds
//! the missing network boundary — std-only (no tokio, no serde: the
//! container is offline), mirroring how the rest of the stack owns its
//! substrates:
//!
//! * [`wire`] — versioned, length-prefixed binary protocol; strict
//!   decoding, exhaustive round-trip tests.
//! * [`server`] — `TcpListener` accept loop; per-connection reader and
//!   writer threads pipeline many in-flight requests per connection into
//!   the pool.
//! * [`admission`] — bounded in-flight gate with a `block` (TCP
//!   backpressure) or `shed` (structured `Overloaded{retry_after}`)
//!   policy, so overload never stalls the pool dispatcher.
//! * [`cache`] — sharded LRU response cache keyed by the full
//!   `(arch, mode, row)` — bit-identical to uncached execution because
//!   every backend is deterministic.
//! * [`client`] — blocking, pipelining Rust client used by the tests,
//!   `examples/mnist_serving.rs`, and `benches/net_throughput.rs`.
//!
//! End to end: `odin serve --listen 127.0.0.1:0 --cache 1024 --admission
//! shed --queue-cap 256` serves the pool over loopback; everything stays
//! hermetic and offline.  See `docs/ARCHITECTURE.md` for the L4 design
//! (wire format table, admission state diagram, cache coherence note).
#![deny(missing_docs)]

pub mod admission;
pub mod cache;
pub mod client;
pub mod server;
pub mod wire;

pub use admission::{AdmissionConfig, AdmissionGate, AdmissionPolicy, Permit};
pub use cache::{CacheKey, CachedScores, ResponseCache};
pub use client::{NetClient, NetError, NetResponse};
pub use server::{Frontend, FrontendConfig};
pub use wire::{Frame, WireErrorKind, WireRequest, WireResponse, WireStatus, WIRE_VERSION};
