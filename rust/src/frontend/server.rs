//! The TCP front-end server: accept loop + per-connection reader/writer
//! threads bridging [`wire`] frames into the engine pool.
//!
//! ```text
//!              accept loop (one thread)
//!                    │ per connection
//!        ┌───────────┴───────────┐
//!        ▼                       ▼
//!  reader thread            writer thread
//!  read_frame ──▶ decode    drain FIFO of outcomes:
//!   │ arch/mode check        • Immediate (cache hit, typed error,
//!   │ cache lookup             Overloaded) — write now
//!   │ admission gate         • Pending — wait for the pool response,
//!   │ pool submit ──────────▶  insert into the cache, release the
//!   ▼ next frame               admission permit, write
//! ```
//!
//! The reader never waits for a response before reading the next frame,
//! so one connection pipelines arbitrarily many in-flight requests into
//! the pool; the writer answers them in submission order (responses
//! carry the request id, so clients may match them however they like).
//! Because admission blocks only the reader while the writer keeps
//! draining permits, a full `block` gate applies TCP backpressure to the
//! client instead of deadlocking.  A peer that stops *reading* responses
//! is torn down once a response write blocks for `WRITE_TIMEOUT` (30 s),
//! which releases every admission permit its queue was holding — one
//! bad client can degrade the shared gate only briefly, never wedge it.
//!
//! A front-end serves one `(arch, mode)` pair — the coordinates of the
//! engines behind the pool.  Requests for any other model are answered
//! with a typed `UnknownModel` error.  Malformed rows are *not* rejected
//! here: they flow to the pool, whose per-request width validation
//! answers them with `WrongRowWidth` — one validation path for local and
//! network callers, regression-tested over the wire.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{Client, MetricsHub, Response, ServeError};

use super::admission::{AdmissionConfig, AdmissionGate, Permit};
use super::cache::{CacheKey, CachedScores, ResponseCache};
use super::wire::{self, Frame, WireErrorKind, WireRequest, WireResponse, WireStatus};

/// Bound on each connection's queued-but-unwritten responses.  Immediate
/// responses (cache hits, typed errors, `Overloaded`) take no admission
/// permit, so without this bound a client that sends requests but never
/// reads responses would grow server memory without limit; a full queue
/// instead blocks the reader, which stops reading frames and lets TCP
/// backpressure throttle the peer.
const WRITER_QUEUE: usize = 1024;

/// How long one response write may block before the connection is
/// declared dead.  A peer that stops *reading* wedges its writer thread
/// mid-`write_frame` while admission permits sit in the queued `Pending`
/// messages behind it; the timeout tears that connection down (dropping
/// the queue releases every permit), so a single non-reading client can
/// starve the shared gate for at most this long.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Front-end configuration: overload policy plus response caching.
#[derive(Clone, Copy, Debug)]
pub struct FrontendConfig {
    /// Admission gate configuration (policy, capacity, retry hint).
    pub admission: AdmissionConfig,
    /// Total response-cache capacity in entries; `0` disables caching.
    pub cache_capacity: usize,
    /// Max concurrently open connections; further accepts are refused
    /// (dropped) until one closes.  Each connection costs two OS
    /// threads, so this — not the admission gate, which only bounds
    /// in-flight *requests* — is what stops a connection flood from
    /// exhausting the process.
    pub max_connections: usize,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            admission: AdmissionConfig::default(),
            cache_capacity: 0,
            max_connections: 1024,
        }
    }
}

struct Shared {
    stop: AtomicBool,
    /// Read-half handles of live connections, kept weakly so a finished
    /// connection closes its socket immediately; `shutdown` upgrades
    /// whatever is still alive to unblock the readers.
    conns: Mutex<Vec<Weak<TcpStream>>>,
    metrics: MetricsHub,
    gate: AdmissionGate,
    cache: Option<ResponseCache>,
    client: Client,
    arch: Arc<str>,
    mode: Arc<str>,
    max_connections: usize,
}

/// A running TCP front-end over an engine pool.
///
/// The front-end borrows the pool through a [`Client`] clone — it does
/// not own the pool.  Shut down in this order: drop local clients, call
/// [`Frontend::shutdown`] (joins every front-end thread), then shut the
/// pool down.
pub struct Frontend {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<Vec<JoinHandle<()>>>>,
}

enum WriterMsg {
    /// Already-resolved response (cache hit, protocol error, shed).
    Immediate(WireResponse),
    /// A pool submission to wait on, then answer.
    Pending {
        id: u64,
        rx: Receiver<std::result::Result<Response, ServeError>>,
        permit: Permit,
        key: Option<CacheKey>,
    },
}

impl Frontend {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral loopback
    /// port) and serve `pool_client`'s engine pool, which must be built
    /// from engines for exactly `arch`/`mode`.
    pub fn spawn(
        listen: &str,
        pool_client: Client,
        arch: &str,
        mode: &str,
        cfg: FrontendConfig,
        metrics: MetricsHub,
    ) -> Result<Frontend> {
        let listener = TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            metrics: metrics.clone(),
            gate: AdmissionGate::new(cfg.admission, metrics.clone()),
            cache: (cfg.cache_capacity > 0)
                .then(|| ResponseCache::new(cfg.cache_capacity, metrics)),
            client: pool_client,
            arch: Arc::from(arch),
            mode: Arc::from(mode),
            max_connections: cfg.max_connections.max(1),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("odin-accept".into())
                .spawn(move || Self::accept_loop(listener, shared))
                .context("spawning accept thread")?
        };
        Ok(Frontend { addr, shared, accept: Some(accept) })
    }

    /// The address the front-end actually bound (resolves `:0` ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    fn accept_loop(listener: TcpListener, shared: Arc<Shared>) -> Vec<JoinHandle<()>> {
        let mut handles = Vec::new();
        loop {
            let stream = match listener.accept() {
                Ok((stream, _peer)) => stream,
                Err(_) => {
                    if shared.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    // Persistent accept errors (e.g. fd exhaustion) must
                    // not busy-spin a core; back off briefly.
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    continue;
                }
            };
            // The shutdown wake-up connect lands here with `stop` set.
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
            // Reap finished connections so a long-running front-end does
            // not accumulate one dead handle per connection ever served
            // (dropping a finished JoinHandle just detaches it), and so
            // `handles.len()` counts live connections for the cap below.
            handles.retain(|h| !h.is_finished());
            if handles.len() >= shared.max_connections {
                // Connection flood: refuse by dropping the socket — each
                // connection costs two OS threads, so accepting past the
                // cap would let idle connections exhaust the process.
                drop(stream);
                continue;
            }
            let _ = stream.set_nodelay(true);
            shared.metrics.record_net_connection();
            let read_half = Arc::new(stream);
            {
                let mut conns = shared.conns.lock().unwrap();
                conns.retain(|w| w.strong_count() > 0);
                conns.push(Arc::downgrade(&read_half));
            }
            let sh = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name("odin-conn".into())
                .spawn(move || Self::connection(read_half, sh));
            if let Ok(h) = spawned {
                handles.push(h);
            }
        }
        handles
    }

    /// One connection: this thread reads and dispatches frames; a paired
    /// writer thread answers them (see module docs for the data flow).
    fn connection(read_half: Arc<TcpStream>, shared: Arc<Shared>) {
        let write_half = match read_half.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let _ = write_half.set_write_timeout(Some(WRITE_TIMEOUT));
        let (wtx, wrx) = mpsc::sync_channel::<WriterMsg>(WRITER_QUEUE);
        let writer = {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("odin-conn-writer".into())
                .spawn(move || Self::writer(write_half, wrx, sh))
        };
        let writer = match writer {
            Ok(h) => h,
            Err(_) => return,
        };
        let mut reader = &*read_half;
        loop {
            match wire::read_frame(&mut reader) {
                Ok(Some(Frame::Request(req))) => {
                    if Self::handle_request(req, &wtx, &shared).is_err() {
                        break; // writer gone (socket died)
                    }
                }
                Ok(Some(Frame::Response(resp))) => {
                    let answer = WireResponse {
                        id: resp.id,
                        status: WireStatus::Error {
                            kind: WireErrorKind::BadRequest,
                            message: "unexpected response frame from client".to_string(),
                        },
                    };
                    if wtx.send(WriterMsg::Immediate(answer)).is_err() {
                        break;
                    }
                }
                // Clean EOF, a malformed frame, or a closed socket all
                // end the connection; queued work still drains.
                Ok(None) | Err(_) => break,
            }
        }
        drop(wtx);
        let _ = writer.join();
        let _ = read_half.shutdown(Shutdown::Both);
    }

    /// Dispatch one decoded request; `Err` means the writer is gone.
    /// Sends into the bounded writer queue, so a peer that stops reading
    /// responses eventually blocks this reader (TCP backpressure) rather
    /// than growing server memory.
    fn handle_request(
        req: WireRequest,
        wtx: &SyncSender<WriterMsg>,
        shared: &Shared,
    ) -> std::result::Result<(), ()> {
        if req.arch.as_str() != &*shared.arch || req.mode.as_str() != &*shared.mode {
            let answer = WireResponse {
                id: req.id,
                status: WireStatus::Error {
                    kind: WireErrorKind::UnknownModel,
                    message: format!(
                        "this front-end serves {}/{}, not {}/{}",
                        shared.arch, shared.mode, req.arch, req.mode
                    ),
                },
            };
            return wtx.send(WriterMsg::Immediate(answer)).map_err(|_| ());
        }
        // Cache lookup comes before admission: a hit costs no pool work,
        // so the hot working set keeps serving even under overload.
        let (key, row) = match shared.cache.as_ref() {
            Some(cache) => {
                let k = CacheKey::new(
                    Arc::clone(&shared.arch),
                    Arc::clone(&shared.mode),
                    req.row,
                );
                if let Some(hit) = cache.get(&k) {
                    let answer = WireResponse {
                        id: req.id,
                        status: WireStatus::Ok {
                            shard: hit.shard,
                            argmax: hit.argmax,
                            cached: true,
                            logits: hit.logits,
                        },
                    };
                    return wtx.send(WriterMsg::Immediate(answer)).map_err(|_| ());
                }
                let row = k.row().to_vec();
                (Some(k), row)
            }
            None => (None, req.row),
        };
        let permit = match shared.gate.admit() {
            Ok(p) => p,
            Err(retry_after_ms) => {
                let answer = WireResponse {
                    id: req.id,
                    status: WireStatus::Overloaded { retry_after_ms },
                };
                return wtx.send(WriterMsg::Immediate(answer)).map_err(|_| ());
            }
        };
        let rx = shared.client.submit(row);
        wtx.send(WriterMsg::Pending { id: req.id, rx, permit, key }).map_err(|_| ())
    }

    /// Writer loop: resolve each queued outcome in order and write it.
    fn writer(mut stream: TcpStream, wrx: Receiver<WriterMsg>, shared: Arc<Shared>) {
        while let Ok(msg) = wrx.recv() {
            let resp = match msg {
                WriterMsg::Immediate(r) => r,
                WriterMsg::Pending { id, rx, permit, key } => {
                    let status = match rx.recv() {
                        Ok(Ok(resp)) => {
                            let scores = CachedScores {
                                logits: resp.prediction.logits,
                                argmax: resp.prediction.argmax,
                                shard: resp.shard as u32,
                            };
                            if let (Some(cache), Some(k)) = (shared.cache.as_ref(), key) {
                                cache.put(k, scores);
                            }
                            WireStatus::Ok {
                                shard: scores.shard,
                                argmax: scores.argmax,
                                cached: false,
                                logits: scores.logits,
                            }
                        }
                        Ok(Err(e)) => WireStatus::Error {
                            kind: error_kind(&e),
                            message: e.to_string(),
                        },
                        Err(_) => WireStatus::Error {
                            kind: WireErrorKind::Shutdown,
                            message: "engine pool stopped".to_string(),
                        },
                    };
                    drop(permit);
                    WireResponse { id, status }
                }
            };
            if wire::write_frame(&mut stream, &Frame::Response(resp)).is_err() {
                // Dead socket: exiting drops the queued messages, whose
                // permits release on drop — admission never leaks slots.
                break;
            }
            shared.metrics.record_net_response();
        }
        let _ = stream.shutdown(Shutdown::Both);
    }

    /// Stop accepting, close every live connection, and join every
    /// front-end thread.  The engine pool is not owned and keeps
    /// running; shut it down separately afterwards.
    pub fn shutdown(mut self) {
        self.stop_impl();
    }

    fn stop_impl(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection (a
        // wildcard bind address is not connectable; use loopback).
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(wake);
        let conn_handles = self.accept.take().map(|h| h.join().unwrap_or_default());
        for conn in self.shared.conns.lock().unwrap().drain(..) {
            if let Some(stream) = conn.upgrade() {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        if let Some(handles) = conn_handles {
            for h in handles {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Frontend {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_impl();
        }
    }
}

fn error_kind(e: &ServeError) -> WireErrorKind {
    match e {
        ServeError::WrongRowWidth { .. } => WireErrorKind::WrongRowWidth,
        ServeError::Backend(_) => WireErrorKind::Backend,
        ServeError::Shutdown => WireErrorKind::Shutdown,
    }
}
