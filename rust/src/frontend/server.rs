//! The TCP front-end server: accept loop, per-connection reader/writer
//! threads, and a fair scheduler bridging [`wire`] frames into the
//! engine pool.
//!
//! ```text
//!              accept loop (one thread; over --max-conns ⇒ typed
//!                    │       TooManyConnections{retry_after}, close)
//!                    │ per connection
//!        ┌───────────┴───────────┐
//!        ▼                       ▼
//!  reader thread            writer thread
//!  read_frame ──▶ decode    drain FIFO of outcomes:
//!   │ arch/mode check        • Immediate (cache hit, typed error,
//!   │ cache lookup             Overloaded) — write now
//!   │ enqueue into this      • Pending — wait for the pool response,
//!   ▼ client's fair queue      insert into the cache, release the
//!                              admission permit, write
//!        per-client queues (bounded; a full queue blocks only
//!        its own reader ⇒ per-connection TCP backpressure)
//!        └──▶ fair scheduler thread (DRR | FIFO):
//!               pick client ─▶ admission gate ─▶ pool submit
//!                               │ full + shed ⇒ Overloaded now
//!                               ▼ full + block ⇒ wait for a permit
//!                            hand Pending to that client's writer
//! ```
//!
//! The reader never waits for a response before reading the next frame,
//! so one connection pipelines arbitrarily many in-flight requests; the
//! writer answers with the request id, so clients match responses
//! however they like.  **Requests no longer flow straight into the
//! pool**: each connection's reader enqueues into its own bounded queue
//! and one scheduler thread drains the queues fairly (deficit
//! round-robin by default, global-FIFO as the measurable control — see
//! [`fairness`](super::fairness)).  A hog pipelining an open-loop flood
//! now queues behind *itself*: its queue fills, its reader blocks, TCP
//! throttles it — while every other client's requests keep reaching the
//! pool at their fair share (property-tested in `tests/fairness.rs`).
//!
//! Because cache hits and protocol rejections are answered by the
//! reader directly (they cost no pool work), they can overtake queued
//! requests of the same connection: responses are matched by id, not by
//! order.  Pool-bound requests of one client always dispatch in their
//! arrival order.
//!
//! The admission gate moved with the dispatch point: the *scheduler*
//! admits, so a full `block` gate pauses dispatch (every queue keeps
//! absorbing until its own bound) and `shed` rejects the fairly-chosen
//! request with `Overloaded` at its dispatch turn.  A peer that stops
//! *reading* responses wedges only itself: the scheduler hands a
//! dispatch to a full writer queue via a non-blocking send, parks at
//! most one outcome per connection, and skips that client until its
//! writer drains — or until the writer's `WRITE_TIMEOUT` (30 s) tears
//! the connection down, which releases every admission permit its queue
//! was holding.  Disconnecting discards a client's undispatched backlog
//! (a dead peer's work must not consume pool capacity).
//!
//! **Connection governance.**  `FrontendConfig::max_connections` caps
//! concurrently open connections; one past the cap is answered with a
//! single typed `TooManyConnections{retry_after}` frame (id 0) and
//! closed — never a silent drop, never stream corruption.  Each
//! connection may introduce itself with a `Hello` frame before its
//! first request; the name labels its fairness counters in the metrics
//! (else it reports as `conn-N`).
//!
//! **Routing.**  A front-end built with [`ServeConfig::serve_pool`]
//! serves one `(arch, mode)` pair; one built with
//! [`ServeConfig::serve_registry`]
//! routes each request by its `(arch, mode)` to the matching pool of a
//! [`ModelRegistry`] — several models behind one listener, each with
//! hot-swappable, epoch-versioned weights (swap frames are answered
//! `Swapped{epoch}`, and a successful swap eagerly purges every cache
//! entry the new epoch outdated).  Requests for an unserved model are
//! answered with a typed `UnknownModel` error naming what *is* served.
//! Malformed rows are *not* rejected here: they flow to the pool, whose
//! per-request width validation answers them with `WrongRowWidth` — one
//! validation path for local and network callers, regression-tested
//! over the wire.
//!
//! **Admission and the cache-hit fast path.**  Cache lookups run on the
//! reader, *before* the fair queue and the admission gate, and a hit is
//! answered immediately — it never takes a queue slot or a permit, so
//! the hot working set keeps serving even while the gate is saturated,
//! and a burst of hits can never leak gate slots (pinned by the
//! loopback tests).  Only requests that actually reach the pool hold a
//! permit, released when their response is written (or their connection
//! dies).

use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, PoisonError, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::{Client, MetricsHub, Response, ServeError};
use crate::util::trace::{Stage, TraceCtx, Tracer};

use super::admission::{AdmissionConfig, AdmissionGate, Permit};
use super::cache::{CacheKey, CachedScores, ResponseCache};
use super::fairness::{ClientId, FairScheduler, FairnessConfig, Next};
use super::framing::{self, WRITE_TIMEOUT};
use super::wire::{
    self, Frame, WireErrorKind, WireRequest, WireResponse, WireStats, WireStatus, WireSwap,
};

/// Bound on each connection's queued-but-unwritten responses.  Immediate
/// responses (cache hits, typed errors, `Overloaded`) take no admission
/// permit, so without this bound a client that sends requests but never
/// reads responses would grow server memory without limit; a full queue
/// instead blocks the reader, which stops reading frames and lets TCP
/// backpressure throttle the peer.  (The fair scheduler never blocks on
/// it: it parks at most one outcome and skips the connection.)
const WRITER_QUEUE: usize = 1024;

// How long one response write may block before the connection is
// declared dead: `framing::WRITE_TIMEOUT` (shared by every wire role).
// A peer that stops *reading* wedges its writer thread mid-`write_frame`
// while admission permits sit in the queued `Pending` messages behind
// it; the timeout tears that connection down (dropping the queue
// releases every permit), so a single non-reading client can hold gate
// slots for at most this long — and it never blocks the fair scheduler,
// which skips writer-full connections.

/// How long the scheduler waits per `next` call before re-checking
/// parked outcomes (writer-full connections) and the stop flag.
const SCHED_TICK: Duration = Duration::from_millis(25);

/// Front-end configuration: overload policy, response caching, and
/// connection governance.
#[derive(Clone, Copy, Debug)]
pub struct FrontendConfig {
    /// Admission gate configuration (policy, capacity, retry hint).
    pub admission: AdmissionConfig,
    /// Total response-cache capacity in entries; `0` disables caching.
    pub cache_capacity: usize,
    /// Max concurrently open connections; one arriving past the cap is
    /// answered with a typed `TooManyConnections{retry_after}` frame and
    /// closed.  Each connection costs two OS threads, so this — not the
    /// admission gate, which only bounds in-flight *requests* — is what
    /// stops a connection flood from exhausting the process.
    pub max_connections: usize,
    /// Backoff hint carried by `TooManyConnections` rejections (ms).
    pub conn_retry_after_ms: u32,
    /// Per-client fair-queuing configuration (policy, DRR quantum,
    /// per-client queue bound).
    pub fairness: FairnessConfig,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            admission: AdmissionConfig::default(),
            cache_capacity: 0,
            max_connections: 1024,
            conn_retry_after_ms: 50,
            fairness: FairnessConfig::default(),
        }
    }
}

/// Builder for a TCP front-end: the listen address plus every serving
/// knob as a named field, terminated by what the front-end serves.
/// This is the one construction surface — the positional
/// `Frontend::spawn` / `Frontend::spawn_registry` entry points are
/// deprecated wrappers over it.
///
/// ```no_run
/// use std::sync::Arc;
/// use odin::coordinator::{BatchPolicy, MetricsHub, ModelRegistry, ModelSpec};
/// use odin::frontend::{AdmissionConfig, ServeConfig};
///
/// let hub = MetricsHub::new();
/// let registry = Arc::new(ModelRegistry::spawn(
///     vec![ModelSpec::synthetic("cnn1", "fast", 1)],
///     BatchPolicy::default(),
///     hub.clone(),
/// )?);
/// let fe = ServeConfig::new("127.0.0.1:0")
///     .cache(1024)
///     .admission(AdmissionConfig::default())
///     .metrics(hub)
///     .serve_registry(registry)?;
/// println!("listening on {}", fe.local_addr());
/// # anyhow::Ok(())
/// ```
///
/// Every knob has the [`FrontendConfig`] default; unset metrics mean a
/// fresh (unshared) [`MetricsHub`].  A [`ServeConfig::tracer`] attaches
/// to that hub's *front-end handle* — engine-pool stages trace only if
/// the pool's own hub clone carried the tracer before the pool was
/// built, so whole-pipeline tracing should attach the tracer to the hub
/// first and pass the hub via [`ServeConfig::metrics`].
#[derive(Clone, Default)]
pub struct ServeConfig {
    listen: String,
    cfg: FrontendConfig,
    metrics: Option<MetricsHub>,
    tracer: Option<Tracer>,
}

impl ServeConfig {
    /// Start from `listen` (e.g. `"127.0.0.1:0"` for an ephemeral
    /// loopback port) with every knob at its default.
    pub fn new(listen: impl Into<String>) -> ServeConfig {
        ServeConfig { listen: listen.into(), ..ServeConfig::default() }
    }

    /// Response-cache capacity in entries (`0` disables caching).
    pub fn cache(mut self, entries: usize) -> ServeConfig {
        self.cfg.cache_capacity = entries;
        self
    }

    /// Admission-gate configuration (policy, capacity, retry hint).
    pub fn admission(mut self, admission: AdmissionConfig) -> ServeConfig {
        self.cfg.admission = admission;
        self
    }

    /// Per-client fair-queuing configuration (policy, DRR quantum,
    /// per-client queue bound).
    pub fn fairness(mut self, fairness: FairnessConfig) -> ServeConfig {
        self.cfg.fairness = fairness;
        self
    }

    /// Max concurrently open connections (see
    /// [`FrontendConfig::max_connections`]).
    pub fn max_connections(mut self, max: usize) -> ServeConfig {
        self.cfg.max_connections = max;
        self
    }

    /// Backoff hint carried by `TooManyConnections` rejections (ms).
    pub fn conn_retry_after_ms(mut self, ms: u32) -> ServeConfig {
        self.cfg.conn_retry_after_ms = ms;
        self
    }

    /// Record serving metrics into `hub` (callers keep a clone to read
    /// reports from); defaults to a fresh hub nobody else sees.
    pub fn metrics(mut self, hub: MetricsHub) -> ServeConfig {
        self.metrics = Some(hub);
        self
    }

    /// Attach a span recorder to the front-end's hub handle (see the
    /// type docs for the whole-pipeline caveat).
    pub fn tracer(mut self, tracer: Tracer) -> ServeConfig {
        self.tracer = Some(tracer);
        self
    }

    /// The assembled [`FrontendConfig`] (what the terminals pass on);
    /// exposed so callers can inspect or persist the effective knobs.
    pub fn frontend_config(&self) -> FrontendConfig {
        self.cfg
    }

    /// Bind and serve one `(arch, mode)` pair over `pool_client`'s
    /// engine pool.  A single-model front-end assumes a **fixed weight
    /// generation**: it caches under epoch 0 and has no swap surface —
    /// pools with mutable weights belong behind
    /// [`ServeConfig::serve_registry`], whose epoch-keyed cache makes
    /// stale reads impossible.
    pub fn serve_pool(self, pool_client: Client, arch: &str, mode: &str) -> Result<Frontend> {
        let router =
            Router::Single { client: pool_client, arch: Arc::from(arch), mode: Arc::from(mode) };
        let (listen, cfg, hub) = self.finish();
        Frontend::spawn_router(&listen, router, cfg, hub)
    }

    /// Bind and serve every model of `registry`, routing each request
    /// by its `(arch, mode)`; swap frames are honored and the cache is
    /// epoch-keyed.
    pub fn serve_registry(self, registry: Arc<ModelRegistry>) -> Result<Frontend> {
        let (listen, cfg, hub) = self.finish();
        Frontend::spawn_router(&listen, Router::Registry(registry), cfg, hub)
    }

    fn finish(self) -> (String, FrontendConfig, MetricsHub) {
        let hub = self.metrics.unwrap_or_default();
        let hub = match self.tracer {
            Some(tracer) => hub.with_tracer(tracer),
            None => hub,
        };
        (self.listen, self.cfg, hub)
    }
}

/// Where requests go: one fixed pool, or a multi-model registry routed
/// by `(arch, mode)`.
enum Router {
    /// One `(arch, mode)` pair over one pool client (always epoch 0 —
    /// single-pool front-ends have no swap surface).
    Single {
        client: Client,
        arch: Arc<str>,
        mode: Arc<str>,
    },
    /// Route per request through a [`ModelRegistry`]; epochs advance
    /// with hot swaps.
    Registry(Arc<ModelRegistry>),
}

impl Router {
    /// The submission client and current weights epoch for a model, or
    /// `None` when this front-end does not serve it.
    fn route(&self, arch: &str, mode: &str) -> Option<(Client, u64)> {
        match self {
            Router::Single { client, arch: a, mode: m } => {
                (arch == &**a && mode == &**m).then(|| (client.clone(), 0))
            }
            Router::Registry(r) => r.route(arch, mode),
        }
    }

    /// Human-readable list of served models for `UnknownModel` errors.
    fn served(&self) -> String {
        match self {
            Router::Single { arch, mode, .. } => format!("{arch}/{mode}"),
            Router::Registry(r) => {
                let names: Vec<String> =
                    r.models().into_iter().map(|(id, _)| id.to_string()).collect();
                names.join(", ")
            }
        }
    }
}

/// One pool-bound request traveling through the fair scheduler: enough
/// to admit, submit, and hand the outcome to the owning connection's
/// writer.
struct Job {
    id: u64,
    row: Vec<u8>,
    pool: Client,
    key: Option<CacheKey>,
    wtx: SyncSender<WriterMsg>,
    /// Trace identity stamped at the reader; carried through the fair
    /// queue, the pool, and the writer so every stage span shares it.
    ctx: TraceCtx,
    /// When the reader decoded the frame: opens the `queue` span (closed
    /// at the scheduler pop) and the root `request` span (closed when the
    /// response frame is written).
    arrival: Instant,
}

struct Shared {
    stop: AtomicBool,
    /// Read-half handles of live connections, kept weakly so a finished
    /// connection closes its socket immediately; `shutdown` upgrades
    /// whatever is still alive to unblock the readers.
    conns: Mutex<Vec<Weak<TcpStream>>>,
    conn_seq: AtomicU64,
    metrics: MetricsHub,
    gate: AdmissionGate,
    cache: Option<ResponseCache>,
    sched: FairScheduler<Job>,
    router: Router,
    max_connections: usize,
    conn_retry_after_ms: u32,
}

/// A running TCP front-end over an engine pool (or several, via a
/// [`ModelRegistry`]).
///
/// The front-end borrows the pool(s) through [`Client`] clones — it
/// does not own them.  Shut down in this order: drop local clients,
/// call [`Frontend::shutdown`] (joins every front-end thread), then
/// shut the pool/registry down.
pub struct Frontend {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<Vec<JoinHandle<()>>>>,
    scheduler: Option<JoinHandle<()>>,
}

enum WriterMsg {
    /// Already-resolved response (cache hit, protocol error, shed, stats
    /// scrape).  Carries the trace context and arrival instant so the
    /// writer can close the root `request` span — answered-immediately
    /// requests must not vanish from the per-stage totals.
    Immediate {
        resp: WireResponse,
        ctx: TraceCtx,
        arrival: Instant,
    },
    /// A pool submission to wait on, then answer.  The permit is `None`
    /// when the scheduler had to park this outcome for a writer-full
    /// connection: a parked outcome releases its admission slot so the
    /// scheduler can never block in `gate.admit()` waiting on a permit
    /// it is itself holding (that was a deadlock with a small gate and
    /// one wedged peer).
    Pending {
        id: u64,
        rx: Receiver<std::result::Result<Response, ServeError>>,
        permit: Option<Permit>,
        key: Option<CacheKey>,
        ctx: TraceCtx,
        arrival: Instant,
    },
}

impl Frontend {
    /// Deprecated positional constructor; see [`ServeConfig`].
    ///
    /// Binds `listen` and serves `pool_client`'s engine pool, which must
    /// be built from engines for exactly `arch`/`mode`.  A single-model
    /// front-end assumes a **fixed weight generation**: it caches under
    /// epoch 0 and has no swap surface.  Do not point it (with a cache
    /// enabled) at a pool whose weights you hot-swap through
    /// [`EnginePool::spawn_versioned`](crate::coordinator::EnginePool::spawn_versioned)
    /// — post-swap lookups would still find pre-swap entries.  Pools
    /// with mutable weights belong behind [`ServeConfig::serve_registry`],
    /// whose epoch-keyed cache makes stale reads impossible.
    #[deprecated(since = "0.2.0", note = "use ServeConfig::new(listen)...serve_pool(...)")]
    pub fn spawn(
        listen: &str,
        pool_client: Client,
        arch: &str,
        mode: &str,
        cfg: FrontendConfig,
        metrics: MetricsHub,
    ) -> Result<Frontend> {
        let router =
            Router::Single { client: pool_client, arch: Arc::from(arch), mode: Arc::from(mode) };
        Self::spawn_router(listen, router, cfg, metrics)
    }

    /// Deprecated positional constructor; see [`ServeConfig`].
    ///
    /// Binds `listen` and serves every model of `registry`, routing each
    /// request by its `(arch, mode)`.  Swap frames are honored: the
    /// registry reloads the model's weights, the response cache's epoch
    /// keying retires all stale entries by construction, and the
    /// front-end eagerly purges them so the capacity is immediately
    /// available to the new epoch.
    #[deprecated(since = "0.2.0", note = "use ServeConfig::new(listen)...serve_registry(...)")]
    pub fn spawn_registry(
        listen: &str,
        registry: Arc<ModelRegistry>,
        cfg: FrontendConfig,
        metrics: MetricsHub,
    ) -> Result<Frontend> {
        Self::spawn_router(listen, Router::Registry(registry), cfg, metrics)
    }

    fn spawn_router(
        listen: &str,
        router: Router,
        cfg: FrontendConfig,
        metrics: MetricsHub,
    ) -> Result<Frontend> {
        let listener = TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            conn_seq: AtomicU64::new(0),
            metrics: metrics.clone(),
            gate: AdmissionGate::new(cfg.admission, metrics.clone()),
            cache: (cfg.cache_capacity > 0)
                .then(|| ResponseCache::new(cfg.cache_capacity, metrics)),
            sched: FairScheduler::new(cfg.fairness),
            router,
            max_connections: cfg.max_connections.max(1),
            conn_retry_after_ms: cfg.conn_retry_after_ms,
        });
        let scheduler = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("odin-sched".into())
                .spawn(move || Self::scheduler_loop(shared))
                .context("spawning scheduler thread")?
        };
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("odin-accept".into())
                .spawn(move || Self::accept_loop(listener, shared))
                .context("spawning accept thread")?
        };
        Ok(Frontend { addr, shared, accept: Some(accept), scheduler: Some(scheduler) })
    }

    /// The address the front-end actually bound (resolves `:0` ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Admission permits currently held (admitted requests whose
    /// response has not been written yet).  Cache hits never hold one;
    /// after all in-flight work drains this returns to zero — exposed so
    /// tests and operators can verify the gate never leaks slots.
    pub fn admission_in_flight(&self) -> usize {
        self.shared.gate.in_flight()
    }

    /// The fair scheduler: pull jobs by the configured policy, admit
    /// them, submit to the pool, and hand the outcome to the owning
    /// connection's writer.  A full writer queue never blocks this
    /// thread: the outcome is parked (at most one per connection) and
    /// the connection is skipped until its writer drains or dies.
    fn scheduler_loop(shared: Arc<Shared>) {
        let mut parked: HashMap<ClientId, (WriterMsg, SyncSender<WriterMsg>)> = HashMap::new();
        loop {
            // Retry parked outcomes first: a drained writer unblocks its
            // connection; a dead one discards the outcome (dropping a
            // parked Pending releases its permit) and its queue.
            let mut still_parked = HashMap::new();
            for (cid, (msg, wtx)) in parked.drain() {
                match wtx.try_send(msg) {
                    Ok(()) => {}
                    Err(TrySendError::Full(msg)) => {
                        still_parked.insert(cid, (msg, wtx));
                    }
                    Err(TrySendError::Disconnected(msg)) => {
                        drop(msg);
                        shared.sched.unregister(cid);
                    }
                }
            }
            parked = still_parked;
            let blocked: Vec<ClientId> = parked.keys().copied().collect();
            match shared.sched.next(&blocked, SCHED_TICK) {
                Next::Stopped => break,
                Next::TimedOut => continue,
                Next::Job(cid, job) => {
                    let (msg, wtx) = Self::dispatch(&shared, job);
                    match wtx.try_send(msg) {
                        Ok(()) => {}
                        Err(TrySendError::Full(mut msg)) => {
                            // Writer queue full (peer not reading): park
                            // the outcome, skip this client until its
                            // writer drains.  Never block: one wedged
                            // peer must not stall everyone's dispatch.
                            // Release the admission slot while parked —
                            // the scheduler must never hold permits
                            // across a blocking admit (deadlock).
                            if let WriterMsg::Pending { permit, .. } = &mut msg {
                                drop(permit.take());
                            }
                            parked.insert(cid, (msg, wtx));
                        }
                        Err(TrySendError::Disconnected(msg)) => {
                            // Connection died mid-dispatch: discard (a
                            // parked Pending's permit releases on drop)
                            // and drop its remaining backlog.
                            drop(msg);
                            shared.sched.unregister(cid);
                        }
                    }
                }
            }
        }
    }

    /// Admit one fairly-chosen job and turn it into the writer outcome.
    /// The scheduler pop closes the job's `queue` span (fair-queue
    /// residency) and the admit call is timed as the `admission` span —
    /// on the shed path too, so rejected requests count in the
    /// breakdown instead of vanishing.
    fn dispatch(shared: &Shared, job: Job) -> (WriterMsg, SyncSender<WriterMsg>) {
        let Job { id, row, pool, key, wtx, ctx, arrival } = job;
        let popped = Instant::now();
        shared.metrics.tracer().span(ctx, Stage::Queue, arrival, popped, 0);
        let msg = match shared.gate.admit() {
            Err(retry_after_ms) => {
                let denied = Instant::now();
                shared.metrics.tracer().span(ctx, Stage::Admission, popped, denied, 0);
                shared.metrics.record_stage_samples(&[
                    (Stage::Queue, stage_us(arrival, popped)),
                    (Stage::Admission, stage_us(popped, denied)),
                ]);
                WriterMsg::Immediate {
                    resp: WireResponse { id, status: WireStatus::Overloaded { retry_after_ms } },
                    ctx,
                    arrival,
                }
            }
            Ok(permit) => {
                let admitted = Instant::now();
                shared.metrics.tracer().span(ctx, Stage::Admission, popped, admitted, 0);
                shared.metrics.record_stage_samples(&[
                    (Stage::Queue, stage_us(arrival, popped)),
                    (Stage::Admission, stage_us(popped, admitted)),
                ]);
                let rx = pool.submit_traced(row, ctx);
                WriterMsg::Pending { id, rx, permit: Some(permit), key, ctx, arrival }
            }
        };
        (msg, wtx)
    }

    fn accept_loop(listener: TcpListener, shared: Arc<Shared>) -> Vec<JoinHandle<()>> {
        let mut handles = Vec::new();
        loop {
            let stream = match listener.accept() {
                Ok((stream, _peer)) => stream,
                Err(_) => {
                    if shared.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    // Persistent accept errors (e.g. fd exhaustion) must
                    // not busy-spin a core; back off briefly.
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    continue;
                }
            };
            // The shutdown wake-up connect lands here with `stop` set.
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
            // Reap finished connections so a long-running front-end does
            // not accumulate one dead handle per connection ever served
            // (dropping a finished JoinHandle just detaches it), and so
            // `handles.len()` counts live connections for the cap below.
            handles.retain(|h| !h.is_finished());
            if handles.len() >= shared.max_connections {
                // Connection flood: refuse with one *typed* frame, then
                // close — the peer learns why and when to retry, and its
                // stream is never corrupted mid-frame.  Each connection
                // costs two OS threads, so accepting past the cap would
                // let idle connections exhaust the process.
                Self::reject_connection(&shared, stream);
                continue;
            }
            let _ = stream.set_nodelay(true);
            shared.metrics.record_net_connection();
            let read_half = Arc::new(stream);
            {
                // The registry holds only `Weak` handles, so a guard
                // poisoned by a panicking peer is still structurally
                // valid — recover it rather than refuse new clients.
                let mut conns = shared.conns.lock().unwrap_or_else(PoisonError::into_inner);
                conns.retain(|w| w.strong_count() > 0);
                conns.push(Arc::downgrade(&read_half));
            }
            let sh = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name("odin-conn".into())
                .spawn(move || Self::connection(read_half, sh));
            if let Ok(h) = spawned {
                handles.push(h);
            }
        }
        handles
    }

    /// Answer an over-cap connection with one typed
    /// `TooManyConnections{retry_after}` frame (id 0) and close it
    /// gently — [`framing::refuse_with_retry`], the refusal path shared
    /// with the proxy tier — on a short-lived thread, so a reject flood
    /// cannot wedge the accept loop on the drain deadline.
    fn reject_connection(shared: &Shared, stream: TcpStream) {
        shared.metrics.record_conn_rejected();
        // An over-cap connection never reaches a reader, so no trace id
        // was stamped and no span is open — but the rejection still
        // counts in the per-stage totals (its `request` lifetime is the
        // accept-to-reject turnaround, effectively zero), so the
        // breakdown's request count stays `net_responses` plus these.
        shared.metrics.record_stage(Stage::Request, 0.0);
        let retry_after_ms = shared.conn_retry_after_ms;
        let spawned = std::thread::Builder::new()
            .name("odin-conn-reject".into())
            .spawn(move || framing::refuse_with_retry(stream, retry_after_ms));
        drop(spawned);
    }

    /// One connection: this thread reads and dispatches frames; a paired
    /// writer thread answers them (see module docs for the data flow).
    fn connection(read_half: Arc<TcpStream>, shared: Arc<Shared>) {
        let write_half = match read_half.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let _ = write_half.set_write_timeout(Some(WRITE_TIMEOUT));
        let (wtx, wrx) = mpsc::sync_channel::<WriterMsg>(WRITER_QUEUE);
        let writer = {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("odin-conn-writer".into())
                .spawn(move || Self::writer(write_half, wrx, sh))
        };
        let writer = match writer {
            Ok(h) => h,
            Err(_) => return,
        };
        // Fairness identity, registered lazily at the first pool-bound
        // request (or named by a preceding Hello frame).
        // relaxed: connection numbers only need uniqueness (the RMW is
        // atomic regardless of ordering); nothing is published through
        // this counter.
        let conn_no = shared.conn_seq.fetch_add(1, Ordering::Relaxed);
        let mut fair: Option<ClientId> = None;
        let mut hello_name: Option<String> = None;
        let mut reader = &*read_half;
        loop {
            match wire::read_frame(&mut reader) {
                Ok(Some(Frame::Request(req))) => {
                    if Self::handle_request(
                        req,
                        &wtx,
                        &shared,
                        conn_no,
                        &mut fair,
                        &mut hello_name,
                    )
                    .is_err()
                    {
                        break; // writer gone (socket died) or scheduler stopped
                    }
                }
                Ok(Some(Frame::Swap(swap))) => {
                    if Self::handle_swap(swap, &wtx, &shared).is_err() {
                        break;
                    }
                }
                Ok(Some(Frame::Stats(stats))) => {
                    if Self::handle_stats(stats, &wtx, &shared).is_err() {
                        break;
                    }
                }
                Ok(Some(Frame::Hello(hello))) => {
                    // Fire and forget: name the connection's fairness
                    // slot.  After registration the name is frozen —
                    // counters are keyed by it — so late Hellos are
                    // ignored.
                    if fair.is_none() {
                        hello_name = Some(hello.name);
                    }
                }
                Ok(Some(Frame::Response(resp))) => {
                    let arrival = Instant::now();
                    let ctx = shared.metrics.tracer().start_trace();
                    let answer = WireResponse {
                        id: resp.id,
                        status: WireStatus::Error {
                            kind: WireErrorKind::BadRequest,
                            message: "unexpected response frame from client".to_string(),
                        },
                    };
                    if wtx.send(WriterMsg::Immediate { resp: answer, ctx, arrival }).is_err() {
                        break;
                    }
                }
                // Clean EOF, a malformed frame, or a closed socket all
                // end the connection; queued work still drains.
                Ok(None) | Err(_) => break,
            }
        }
        drop(wtx);
        // Discard the undispatched backlog: a dead peer's queued work
        // must not consume pool capacity (already-admitted requests
        // complete and release their permits when the writer exits).
        if let Some(cid) = fair {
            shared.sched.unregister(cid);
        }
        let _ = writer.join();
        let _ = read_half.shutdown(Shutdown::Both);
    }

    /// Dispatch one decoded request; `Err` means the connection is done
    /// (writer gone or scheduler stopped).  Cache hits and protocol
    /// rejections are answered immediately through the bounded writer
    /// queue (blocking this reader is per-connection backpressure);
    /// pool-bound work is enqueued into this client's fair queue, whose
    /// bound likewise blocks only this reader.
    fn handle_request(
        req: WireRequest,
        wtx: &SyncSender<WriterMsg>,
        shared: &Shared,
        conn_no: u64,
        fair: &mut Option<ClientId>,
        hello_name: &mut Option<String>,
    ) -> std::result::Result<(), ()> {
        // The trace identity is stamped here, at the L4 reader — every
        // span this request produces (queue, admission, dispatch, batch,
        // exec, write, and the root request span) shares this id, and the
        // id decides sampling once for the whole trace.  With tracing
        // disabled `start_trace` touches no atomics at all.
        let arrival = Instant::now();
        let ctx = shared.metrics.tracer().start_trace();
        let (client, epoch) = match shared.router.route(&req.arch, &req.mode) {
            Some(route) => route,
            None => {
                let answer = WireResponse {
                    id: req.id,
                    status: WireStatus::Error {
                        kind: WireErrorKind::UnknownModel,
                        message: format!(
                            "this front-end serves [{}], not {}/{}",
                            shared.router.served(),
                            req.arch,
                            req.mode
                        ),
                    },
                };
                return wtx.send(WriterMsg::Immediate { resp: answer, ctx, arrival }).map_err(|_| ());
            }
        };
        // Cache lookup comes before fair queuing and admission: a hit
        // costs no pool work, so it is answered even when the gate is
        // full — and it must NOT acquire a queue slot or a permit (a
        // saturated gate still serves hits; a burst of hits cannot leak
        // slots).  The key carries the model's *current* epoch, so
        // entries from before a hot swap can never be served after it.
        let (key, row) = match shared.cache.as_ref() {
            Some(cache) => {
                // Single-model front-ends reuse their interned name Arcs
                // (zero allocation, as before multi-model routing); the
                // registry path interns per request.
                let (arch, mode) = match &shared.router {
                    Router::Single { arch, mode, .. } => (Arc::clone(arch), Arc::clone(mode)),
                    Router::Registry(_) => {
                        (Arc::from(req.arch.as_str()), Arc::from(req.mode.as_str()))
                    }
                };
                let k = CacheKey::new(arch, mode, epoch, req.row);
                if let Some(hit) = cache.get(&k) {
                    // A cache hit skips queue/admission/pool entirely,
                    // but its root `request` span still closes at the
                    // writer — hits must not vanish from the totals.
                    let answer = WireResponse {
                        id: req.id,
                        status: WireStatus::Ok {
                            shard: hit.shard,
                            argmax: hit.argmax,
                            cached: true,
                            epoch: hit.epoch,
                            logits: hit.logits,
                        },
                    };
                    return wtx.send(WriterMsg::Immediate { resp: answer, ctx, arrival }).map_err(|_| ());
                }
                let row = k.row().to_vec();
                (Some(k), row)
            }
            None => (None, req.row),
        };
        // Register the fairness slot on first pool-bound work, under the
        // Hello-chosen name when one arrived first.
        let cid = match *fair {
            Some(cid) => cid,
            None => {
                let name = hello_name.take().unwrap_or_else(|| format!("conn-{conn_no}"));
                let counters = shared.metrics.register_client(&name);
                let cid = shared.sched.register(counters);
                *fair = Some(cid);
                cid
            }
        };
        let job = Job { id: req.id, row, pool: client, key, wtx: wtx.clone(), ctx, arrival };
        shared.sched.enqueue(cid, 1, job).map_err(|_| ())
    }

    /// Handle one hot-swap frame.  Swaps are admin operations: they take
    /// no admission permit and are answered immediately (`Swapped` with
    /// the new epoch, or a typed error).  A successful swap eagerly
    /// purges every response-cache entry of the model's older epochs —
    /// they are already unreachable by construction (the epoch is in the
    /// key), purging them returns the capacity to the new epoch *now*
    /// instead of waiting for LRU pressure.  `Err` means the writer is
    /// gone.
    fn handle_swap(
        swap: WireSwap,
        wtx: &SyncSender<WriterMsg>,
        shared: &Shared,
    ) -> std::result::Result<(), ()> {
        let arrival = Instant::now();
        let ctx = shared.metrics.tracer().start_trace();
        let status = match &shared.router {
            Router::Single { .. } => WireStatus::Error {
                kind: WireErrorKind::BadRequest,
                message: "hot swap needs a multi-model front-end (serve with --model)"
                    .to_string(),
            },
            Router::Registry(registry) => {
                if registry.route(&swap.arch, &swap.mode).is_none() {
                    WireStatus::Error {
                        kind: WireErrorKind::UnknownModel,
                        message: format!(
                            "this front-end serves [{}], not {}/{}",
                            shared.router.served(),
                            swap.arch,
                            swap.mode
                        ),
                    }
                } else {
                    match registry.swap_seed(&swap.arch, &swap.mode, swap.seed) {
                        Ok(epoch) => {
                            if let Some(cache) = shared.cache.as_ref() {
                                let purged =
                                    cache.purge_stale(&swap.arch, &swap.mode, epoch);
                                shared.metrics.record_cache_stale_purge(purged as u64);
                            }
                            WireStatus::Swapped { epoch }
                        }
                        Err(e) => WireStatus::Error {
                            kind: WireErrorKind::Backend,
                            message: format!("swap failed: {e:#}"),
                        },
                    }
                }
            }
        };
        let resp = WireResponse { id: swap.id, status };
        wtx.send(WriterMsg::Immediate { resp, ctx, arrival }).map_err(|_| ())
    }

    /// Handle one stats frame: snapshot the hub's [`MetricsReport`]
    /// (per-stage percentiles included) and answer it as JSON — a live
    /// server is scraped over the wire without being restarted.  With
    /// `reset`, the per-stage summaries are drained *after* the snapshot,
    /// so consecutive scrapes see disjoint windows (how `loadgen`
    /// attributes stages per scenario).  Stats frames are admin
    /// operations like swaps: no admission permit, answered immediately.
    /// `Err` means the writer is gone.
    fn handle_stats(
        stats: WireStats,
        wtx: &SyncSender<WriterMsg>,
        shared: &Shared,
    ) -> std::result::Result<(), ()> {
        let arrival = Instant::now();
        let ctx = shared.metrics.tracer().start_trace();
        let json = shared.metrics.report_with_stage_reset(stats.reset).to_json();
        let resp = WireResponse { id: stats.id, status: WireStatus::Stats { json } };
        wtx.send(WriterMsg::Immediate { resp, ctx, arrival }).map_err(|_| ())
    }

    /// Writer loop: resolve each queued outcome in order and write it.
    /// Every outcome closes its `write` span (serialize + syscall) and
    /// its root `request` span here, right where the frame leaves the
    /// process — so cache hits, typed rejections, and pool responses all
    /// count once in the per-stage totals, exactly when they count in
    /// `net_responses`.
    fn writer(mut stream: TcpStream, wrx: Receiver<WriterMsg>, shared: Arc<Shared>) {
        while let Ok(msg) = wrx.recv() {
            let (resp, ctx, arrival) = match msg {
                WriterMsg::Immediate { resp, ctx, arrival } => (resp, ctx, arrival),
                WriterMsg::Pending { id, rx, permit, key, ctx, arrival } => {
                    let status = match rx.recv() {
                        Ok(Ok(resp)) => {
                            let scores = CachedScores {
                                logits: resp.prediction.logits,
                                argmax: resp.prediction.argmax,
                                shard: resp.shard as u32,
                                epoch: resp.epoch,
                            };
                            if let (Some(cache), Some(k)) = (shared.cache.as_ref(), key) {
                                // Insert under the epoch the response
                                // *executed* on — a swap may have landed
                                // after admission, and an entry must
                                // never sit under an epoch whose engine
                                // did not produce its bytes.  And only
                                // if that epoch is still current: a
                                // pre-swap straggler's entry would be
                                // unreachable dead weight, re-occupying
                                // capacity the eager purge reclaimed.
                                let current = shared
                                    .router
                                    .route(k.arch(), k.mode())
                                    .map(|(_, e)| e)
                                    .unwrap_or(resp.epoch);
                                if resp.epoch >= current {
                                    cache.put(k.with_epoch(resp.epoch), scores);
                                }
                            }
                            WireStatus::Ok {
                                shard: scores.shard,
                                argmax: scores.argmax,
                                cached: false,
                                epoch: scores.epoch,
                                logits: scores.logits,
                            }
                        }
                        Ok(Err(e)) => WireStatus::Error {
                            kind: error_kind(&e),
                            message: e.to_string(),
                        },
                        Err(_) => WireStatus::Error {
                            kind: WireErrorKind::Shutdown,
                            message: "engine pool stopped".to_string(),
                        },
                    };
                    drop(permit);
                    (WireResponse { id, status }, ctx, arrival)
                }
            };
            let wstart = Instant::now();
            if wire::write_frame(&mut stream, &Frame::Response(resp)).is_err() {
                // Dead socket: exiting drops the queued messages, whose
                // permits release on drop — admission never leaks slots.
                break;
            }
            let done = Instant::now();
            shared.metrics.tracer().span(ctx, Stage::Write, wstart, done, 0);
            shared.metrics.tracer().span(ctx, Stage::Request, arrival, done, 0);
            shared.metrics.record_stage_samples(&[
                (Stage::Write, stage_us(wstart, done)),
                (Stage::Request, stage_us(arrival, done)),
            ]);
            shared.metrics.record_net_response();
        }
        let _ = stream.shutdown(Shutdown::Both);
    }

    /// Stop accepting, close every live connection, and join every
    /// front-end thread (scheduler included; its undispatched queues are
    /// dropped).  The engine pool is not owned and keeps running; shut
    /// it down separately afterwards.
    pub fn shutdown(mut self) {
        self.stop_impl();
    }

    fn stop_impl(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Stop the scheduler first: readers blocked enqueueing wake with
        // a closed error and fall out of their loops.
        self.shared.sched.stop();
        // Wake the blocking accept with a throwaway connection (a
        // wildcard bind address is not connectable; use loopback).
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(wake);
        let conn_handles = self.accept.take().map(|h| h.join().unwrap_or_default());
        // Recover a poisoned registry: shutdown must still sever every
        // surviving connection even if some reader thread panicked.
        for conn in
            self.shared.conns.lock().unwrap_or_else(PoisonError::into_inner).drain(..)
        {
            if let Some(stream) = conn.upgrade() {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        if let Some(handles) = conn_handles {
            for h in handles {
                let _ = h.join();
            }
        }
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Frontend {
    fn drop(&mut self) {
        if self.accept.is_some() || self.scheduler.is_some() {
            self.stop_impl();
        }
    }
}

/// Span duration in microseconds, clamped to zero if the clock reads
/// backwards across threads.
fn stage_us(from: Instant, to: Instant) -> f64 {
    to.saturating_duration_since(from).as_secs_f64() * 1e6
}

fn error_kind(e: &ServeError) -> WireErrorKind {
    match e {
        ServeError::WrongRowWidth { .. } => WireErrorKind::WrongRowWidth,
        ServeError::Backend(_) => WireErrorKind::Backend,
        ServeError::Shutdown => WireErrorKind::Shutdown,
    }
}
