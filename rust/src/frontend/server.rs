//! The TCP front-end server: accept loop + per-connection reader/writer
//! threads bridging [`wire`] frames into the engine pool.
//!
//! ```text
//!              accept loop (one thread)
//!                    │ per connection
//!        ┌───────────┴───────────┐
//!        ▼                       ▼
//!  reader thread            writer thread
//!  read_frame ──▶ decode    drain FIFO of outcomes:
//!   │ arch/mode check        • Immediate (cache hit, typed error,
//!   │ cache lookup             Overloaded) — write now
//!   │ admission gate         • Pending — wait for the pool response,
//!   │ pool submit ──────────▶  insert into the cache, release the
//!   ▼ next frame               admission permit, write
//! ```
//!
//! The reader never waits for a response before reading the next frame,
//! so one connection pipelines arbitrarily many in-flight requests into
//! the pool; the writer answers them in submission order (responses
//! carry the request id, so clients may match them however they like).
//! Because admission blocks only the reader while the writer keeps
//! draining permits, a full `block` gate applies TCP backpressure to the
//! client instead of deadlocking.  A peer that stops *reading* responses
//! is torn down once a response write blocks for `WRITE_TIMEOUT` (30 s),
//! which releases every admission permit its queue was holding — one
//! bad client can degrade the shared gate only briefly, never wedge it.
//!
//! **Routing.**  A front-end built with [`Frontend::spawn`] serves one
//! `(arch, mode)` pair; one built with [`Frontend::spawn_registry`]
//! routes each request by its `(arch, mode)` to the matching pool of a
//! [`ModelRegistry`] — several models behind one listener, each with
//! hot-swappable, epoch-versioned weights (swap frames are answered
//! `Swapped{epoch}`).  Requests for an unserved model are answered with
//! a typed `UnknownModel` error naming what *is* served.  Malformed
//! rows are *not* rejected here: they flow to the pool, whose
//! per-request width validation answers them with `WrongRowWidth` — one
//! validation path for local and network callers, regression-tested
//! over the wire.
//!
//! **Admission and the cache-hit fast path.**  Cache lookups run
//! *before* the admission gate and a hit is answered immediately — it
//! never acquires a permit, so the hot working set keeps serving even
//! while the gate is saturated, and a burst of hits can never leak gate
//! slots (pinned by the loopback tests).  Only requests that actually
//! reach the pool hold a permit, released when their response is
//! written (or their connection dies).

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::registry::ModelRegistry;
use crate::coordinator::{Client, MetricsHub, Response, ServeError};

use super::admission::{AdmissionConfig, AdmissionGate, Permit};
use super::cache::{CacheKey, CachedScores, ResponseCache};
use super::wire::{self, Frame, WireErrorKind, WireRequest, WireResponse, WireStatus, WireSwap};

/// Bound on each connection's queued-but-unwritten responses.  Immediate
/// responses (cache hits, typed errors, `Overloaded`) take no admission
/// permit, so without this bound a client that sends requests but never
/// reads responses would grow server memory without limit; a full queue
/// instead blocks the reader, which stops reading frames and lets TCP
/// backpressure throttle the peer.
const WRITER_QUEUE: usize = 1024;

/// How long one response write may block before the connection is
/// declared dead.  A peer that stops *reading* wedges its writer thread
/// mid-`write_frame` while admission permits sit in the queued `Pending`
/// messages behind it; the timeout tears that connection down (dropping
/// the queue releases every permit), so a single non-reading client can
/// starve the shared gate for at most this long.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Front-end configuration: overload policy plus response caching.
#[derive(Clone, Copy, Debug)]
pub struct FrontendConfig {
    /// Admission gate configuration (policy, capacity, retry hint).
    pub admission: AdmissionConfig,
    /// Total response-cache capacity in entries; `0` disables caching.
    pub cache_capacity: usize,
    /// Max concurrently open connections; further accepts are refused
    /// (dropped) until one closes.  Each connection costs two OS
    /// threads, so this — not the admission gate, which only bounds
    /// in-flight *requests* — is what stops a connection flood from
    /// exhausting the process.
    pub max_connections: usize,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            admission: AdmissionConfig::default(),
            cache_capacity: 0,
            max_connections: 1024,
        }
    }
}

/// Where requests go: one fixed pool, or a multi-model registry routed
/// by `(arch, mode)`.
enum Router {
    /// One `(arch, mode)` pair over one pool client (always epoch 0 —
    /// single-pool front-ends have no swap surface).
    Single {
        client: Client,
        arch: Arc<str>,
        mode: Arc<str>,
    },
    /// Route per request through a [`ModelRegistry`]; epochs advance
    /// with hot swaps.
    Registry(Arc<ModelRegistry>),
}

impl Router {
    /// The submission client and current weights epoch for a model, or
    /// `None` when this front-end does not serve it.
    fn route(&self, arch: &str, mode: &str) -> Option<(Client, u64)> {
        match self {
            Router::Single { client, arch: a, mode: m } => {
                (arch == &**a && mode == &**m).then(|| (client.clone(), 0))
            }
            Router::Registry(r) => r.route(arch, mode),
        }
    }

    /// Human-readable list of served models for `UnknownModel` errors.
    fn served(&self) -> String {
        match self {
            Router::Single { arch, mode, .. } => format!("{arch}/{mode}"),
            Router::Registry(r) => {
                let names: Vec<String> =
                    r.models().into_iter().map(|(id, _)| id.to_string()).collect();
                names.join(", ")
            }
        }
    }
}

struct Shared {
    stop: AtomicBool,
    /// Read-half handles of live connections, kept weakly so a finished
    /// connection closes its socket immediately; `shutdown` upgrades
    /// whatever is still alive to unblock the readers.
    conns: Mutex<Vec<Weak<TcpStream>>>,
    metrics: MetricsHub,
    gate: AdmissionGate,
    cache: Option<ResponseCache>,
    router: Router,
    max_connections: usize,
}

/// A running TCP front-end over an engine pool (or several, via a
/// [`ModelRegistry`]).
///
/// The front-end borrows the pool(s) through [`Client`] clones — it
/// does not own them.  Shut down in this order: drop local clients,
/// call [`Frontend::shutdown`] (joins every front-end thread), then
/// shut the pool/registry down.
pub struct Frontend {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<Vec<JoinHandle<()>>>>,
}

enum WriterMsg {
    /// Already-resolved response (cache hit, protocol error, shed).
    Immediate(WireResponse),
    /// A pool submission to wait on, then answer.
    Pending {
        id: u64,
        rx: Receiver<std::result::Result<Response, ServeError>>,
        permit: Permit,
        key: Option<CacheKey>,
    },
}

impl Frontend {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral loopback
    /// port) and serve `pool_client`'s engine pool, which must be built
    /// from engines for exactly `arch`/`mode`.
    ///
    /// A single-model front-end assumes a **fixed weight generation**:
    /// it caches under epoch 0 and has no swap surface.  Do not point it
    /// (with a cache enabled) at a pool whose weights you hot-swap
    /// through [`EnginePool::spawn_versioned`](crate::coordinator::EnginePool::spawn_versioned)
    /// — post-swap lookups would still find pre-swap entries.  Pools
    /// with mutable weights belong behind [`Frontend::spawn_registry`],
    /// whose epoch-keyed cache makes stale reads impossible.
    pub fn spawn(
        listen: &str,
        pool_client: Client,
        arch: &str,
        mode: &str,
        cfg: FrontendConfig,
        metrics: MetricsHub,
    ) -> Result<Frontend> {
        let router =
            Router::Single { client: pool_client, arch: Arc::from(arch), mode: Arc::from(mode) };
        Self::spawn_router(listen, router, cfg, metrics)
    }

    /// Bind `listen` and serve every model of `registry`, routing each
    /// request by its `(arch, mode)`.  Swap frames are honored: the
    /// registry reloads the model's weights and the response cache's
    /// epoch keying retires all stale entries automatically.
    pub fn spawn_registry(
        listen: &str,
        registry: Arc<ModelRegistry>,
        cfg: FrontendConfig,
        metrics: MetricsHub,
    ) -> Result<Frontend> {
        Self::spawn_router(listen, Router::Registry(registry), cfg, metrics)
    }

    fn spawn_router(
        listen: &str,
        router: Router,
        cfg: FrontendConfig,
        metrics: MetricsHub,
    ) -> Result<Frontend> {
        let listener = TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            metrics: metrics.clone(),
            gate: AdmissionGate::new(cfg.admission, metrics.clone()),
            cache: (cfg.cache_capacity > 0)
                .then(|| ResponseCache::new(cfg.cache_capacity, metrics)),
            router,
            max_connections: cfg.max_connections.max(1),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("odin-accept".into())
                .spawn(move || Self::accept_loop(listener, shared))
                .context("spawning accept thread")?
        };
        Ok(Frontend { addr, shared, accept: Some(accept) })
    }

    /// The address the front-end actually bound (resolves `:0` ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Admission permits currently held (admitted requests whose
    /// response has not been written yet).  Cache hits never hold one;
    /// after all in-flight work drains this returns to zero — exposed so
    /// tests and operators can verify the gate never leaks slots.
    pub fn admission_in_flight(&self) -> usize {
        self.shared.gate.in_flight()
    }

    fn accept_loop(listener: TcpListener, shared: Arc<Shared>) -> Vec<JoinHandle<()>> {
        let mut handles = Vec::new();
        loop {
            let stream = match listener.accept() {
                Ok((stream, _peer)) => stream,
                Err(_) => {
                    if shared.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    // Persistent accept errors (e.g. fd exhaustion) must
                    // not busy-spin a core; back off briefly.
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    continue;
                }
            };
            // The shutdown wake-up connect lands here with `stop` set.
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
            // Reap finished connections so a long-running front-end does
            // not accumulate one dead handle per connection ever served
            // (dropping a finished JoinHandle just detaches it), and so
            // `handles.len()` counts live connections for the cap below.
            handles.retain(|h| !h.is_finished());
            if handles.len() >= shared.max_connections {
                // Connection flood: refuse by dropping the socket — each
                // connection costs two OS threads, so accepting past the
                // cap would let idle connections exhaust the process.
                drop(stream);
                continue;
            }
            let _ = stream.set_nodelay(true);
            shared.metrics.record_net_connection();
            let read_half = Arc::new(stream);
            {
                let mut conns = shared.conns.lock().unwrap();
                conns.retain(|w| w.strong_count() > 0);
                conns.push(Arc::downgrade(&read_half));
            }
            let sh = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name("odin-conn".into())
                .spawn(move || Self::connection(read_half, sh));
            if let Ok(h) = spawned {
                handles.push(h);
            }
        }
        handles
    }

    /// One connection: this thread reads and dispatches frames; a paired
    /// writer thread answers them (see module docs for the data flow).
    fn connection(read_half: Arc<TcpStream>, shared: Arc<Shared>) {
        let write_half = match read_half.try_clone() {
            Ok(s) => s,
            Err(_) => return,
        };
        let _ = write_half.set_write_timeout(Some(WRITE_TIMEOUT));
        let (wtx, wrx) = mpsc::sync_channel::<WriterMsg>(WRITER_QUEUE);
        let writer = {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("odin-conn-writer".into())
                .spawn(move || Self::writer(write_half, wrx, sh))
        };
        let writer = match writer {
            Ok(h) => h,
            Err(_) => return,
        };
        let mut reader = &*read_half;
        loop {
            match wire::read_frame(&mut reader) {
                Ok(Some(Frame::Request(req))) => {
                    if Self::handle_request(req, &wtx, &shared).is_err() {
                        break; // writer gone (socket died)
                    }
                }
                Ok(Some(Frame::Swap(swap))) => {
                    if Self::handle_swap(swap, &wtx, &shared).is_err() {
                        break;
                    }
                }
                Ok(Some(Frame::Response(resp))) => {
                    let answer = WireResponse {
                        id: resp.id,
                        status: WireStatus::Error {
                            kind: WireErrorKind::BadRequest,
                            message: "unexpected response frame from client".to_string(),
                        },
                    };
                    if wtx.send(WriterMsg::Immediate(answer)).is_err() {
                        break;
                    }
                }
                // Clean EOF, a malformed frame, or a closed socket all
                // end the connection; queued work still drains.
                Ok(None) | Err(_) => break,
            }
        }
        drop(wtx);
        let _ = writer.join();
        let _ = read_half.shutdown(Shutdown::Both);
    }

    /// Dispatch one decoded request; `Err` means the writer is gone.
    /// Sends into the bounded writer queue, so a peer that stops reading
    /// responses eventually blocks this reader (TCP backpressure) rather
    /// than growing server memory.
    fn handle_request(
        req: WireRequest,
        wtx: &SyncSender<WriterMsg>,
        shared: &Shared,
    ) -> std::result::Result<(), ()> {
        let (client, epoch) = match shared.router.route(&req.arch, &req.mode) {
            Some(route) => route,
            None => {
                let answer = WireResponse {
                    id: req.id,
                    status: WireStatus::Error {
                        kind: WireErrorKind::UnknownModel,
                        message: format!(
                            "this front-end serves [{}], not {}/{}",
                            shared.router.served(),
                            req.arch,
                            req.mode
                        ),
                    },
                };
                return wtx.send(WriterMsg::Immediate(answer)).map_err(|_| ());
            }
        };
        // Cache lookup comes before admission: a hit costs no pool work,
        // so the hot working set keeps serving even under overload — and
        // it must NOT acquire an admission permit (a saturated gate
        // still serves hits; a burst of hits cannot leak slots).  The
        // key carries the model's *current* epoch, so entries from
        // before a hot swap can never be served after it.
        let (key, row) = match shared.cache.as_ref() {
            Some(cache) => {
                // Single-model front-ends reuse their interned name Arcs
                // (zero allocation, as before multi-model routing); the
                // registry path interns per request.
                let (arch, mode) = match &shared.router {
                    Router::Single { arch, mode, .. } => (Arc::clone(arch), Arc::clone(mode)),
                    Router::Registry(_) => {
                        (Arc::from(req.arch.as_str()), Arc::from(req.mode.as_str()))
                    }
                };
                let k = CacheKey::new(arch, mode, epoch, req.row);
                if let Some(hit) = cache.get(&k) {
                    let answer = WireResponse {
                        id: req.id,
                        status: WireStatus::Ok {
                            shard: hit.shard,
                            argmax: hit.argmax,
                            cached: true,
                            epoch: hit.epoch,
                            logits: hit.logits,
                        },
                    };
                    return wtx.send(WriterMsg::Immediate(answer)).map_err(|_| ());
                }
                let row = k.row().to_vec();
                (Some(k), row)
            }
            None => (None, req.row),
        };
        let permit = match shared.gate.admit() {
            Ok(p) => p,
            Err(retry_after_ms) => {
                let answer = WireResponse {
                    id: req.id,
                    status: WireStatus::Overloaded { retry_after_ms },
                };
                return wtx.send(WriterMsg::Immediate(answer)).map_err(|_| ());
            }
        };
        let rx = client.submit(row);
        wtx.send(WriterMsg::Pending { id: req.id, rx, permit, key }).map_err(|_| ())
    }

    /// Handle one hot-swap frame.  Swaps are admin operations: they take
    /// no admission permit and are answered immediately (`Swapped` with
    /// the new epoch, or a typed error).  `Err` means the writer is
    /// gone.
    fn handle_swap(
        swap: WireSwap,
        wtx: &SyncSender<WriterMsg>,
        shared: &Shared,
    ) -> std::result::Result<(), ()> {
        let status = match &shared.router {
            Router::Single { .. } => WireStatus::Error {
                kind: WireErrorKind::BadRequest,
                message: "hot swap needs a multi-model front-end (serve with --model)"
                    .to_string(),
            },
            Router::Registry(registry) => {
                if registry.route(&swap.arch, &swap.mode).is_none() {
                    WireStatus::Error {
                        kind: WireErrorKind::UnknownModel,
                        message: format!(
                            "this front-end serves [{}], not {}/{}",
                            shared.router.served(),
                            swap.arch,
                            swap.mode
                        ),
                    }
                } else {
                    match registry.swap_seed(&swap.arch, &swap.mode, swap.seed) {
                        Ok(epoch) => WireStatus::Swapped { epoch },
                        Err(e) => WireStatus::Error {
                            kind: WireErrorKind::Backend,
                            message: format!("swap failed: {e:#}"),
                        },
                    }
                }
            }
        };
        wtx.send(WriterMsg::Immediate(WireResponse { id: swap.id, status })).map_err(|_| ())
    }

    /// Writer loop: resolve each queued outcome in order and write it.
    fn writer(mut stream: TcpStream, wrx: Receiver<WriterMsg>, shared: Arc<Shared>) {
        while let Ok(msg) = wrx.recv() {
            let resp = match msg {
                WriterMsg::Immediate(r) => r,
                WriterMsg::Pending { id, rx, permit, key } => {
                    let status = match rx.recv() {
                        Ok(Ok(resp)) => {
                            let scores = CachedScores {
                                logits: resp.prediction.logits,
                                argmax: resp.prediction.argmax,
                                shard: resp.shard as u32,
                                epoch: resp.epoch,
                            };
                            if let (Some(cache), Some(k)) = (shared.cache.as_ref(), key) {
                                // Insert under the epoch the response
                                // *executed* on — a swap may have landed
                                // after admission, and an entry must
                                // never sit under an epoch whose engine
                                // did not produce its bytes.
                                cache.put(k.with_epoch(resp.epoch), scores);
                            }
                            WireStatus::Ok {
                                shard: scores.shard,
                                argmax: scores.argmax,
                                cached: false,
                                epoch: scores.epoch,
                                logits: scores.logits,
                            }
                        }
                        Ok(Err(e)) => WireStatus::Error {
                            kind: error_kind(&e),
                            message: e.to_string(),
                        },
                        Err(_) => WireStatus::Error {
                            kind: WireErrorKind::Shutdown,
                            message: "engine pool stopped".to_string(),
                        },
                    };
                    drop(permit);
                    WireResponse { id, status }
                }
            };
            if wire::write_frame(&mut stream, &Frame::Response(resp)).is_err() {
                // Dead socket: exiting drops the queued messages, whose
                // permits release on drop — admission never leaks slots.
                break;
            }
            shared.metrics.record_net_response();
        }
        let _ = stream.shutdown(Shutdown::Both);
    }

    /// Stop accepting, close every live connection, and join every
    /// front-end thread.  The engine pool is not owned and keeps
    /// running; shut it down separately afterwards.
    pub fn shutdown(mut self) {
        self.stop_impl();
    }

    fn stop_impl(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection (a
        // wildcard bind address is not connectable; use loopback).
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(wake);
        let conn_handles = self.accept.take().map(|h| h.join().unwrap_or_default());
        for conn in self.shared.conns.lock().unwrap().drain(..) {
            if let Some(stream) = conn.upgrade() {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        if let Some(handles) = conn_handles {
            for h in handles {
                let _ = h.join();
            }
        }
    }
}

impl Drop for Frontend {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_impl();
        }
    }
}

fn error_kind(e: &ServeError) -> WireErrorKind {
    match e {
        ServeError::WrongRowWidth { .. } => WireErrorKind::WrongRowWidth,
        ServeError::Backend(_) => WireErrorKind::Backend,
        ServeError::Shutdown => WireErrorKind::Shutdown,
    }
}
