//! End-to-end driver (EXPERIMENTS.md §E2E): serve the whole test split
//! through the dynamic-batching coordinator, measuring accuracy,
//! wall-clock latency/throughput, and the simulated in-PCRAM cost per
//! request.  Runs hermetically on the SimBackend; with `make artifacts`
//! the real weights and the real synth-MNIST split are served (accuracy
//! is only meaningful then).
//!
//! ```bash
//! cargo run --release --example mnist_serving
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;
use odin::coordinator::{BatchPolicy, Engine, MetricsHub, Server, SYNTHETIC_SEED};
use odin::dataset::TestSet;

const CLIENT_THREADS: usize = 8;

fn main() -> Result<()> {
    let arch = std::env::args().nth(1).unwrap_or_else(|| "cnn1".into());
    let metrics = MetricsHub::new();
    let arch_f = arch.clone();
    let (server, client) = Server::spawn(
        move || Engine::sim_auto("artifacts", &arch_f, "fast"),
        BatchPolicy::default(),
        metrics.clone(),
    )?;

    let test = Arc::new(TestSet::load_or_synthetic("artifacts", 2048, SYNTHETIC_SEED)?);
    let n = test.len();
    println!("serving {n} requests for {arch}/fast [sim] from {CLIENT_THREADS} client threads ...");

    let correct = Arc::new(AtomicUsize::new(0));
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for t in 0..CLIENT_THREADS {
        let client = client.clone();
        let test = Arc::clone(&test);
        let correct = Arc::clone(&correct);
        handles.push(std::thread::spawn(move || {
            for i in (t..test.len()).step_by(CLIENT_THREADS) {
                let s = &test.samples[i];
                if let Ok(resp) = client.infer_blocking(s.image.clone()) {
                    if resp.prediction.argmax == s.label {
                        correct.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    drop(client); // release the request channel so the batcher loop exits
    server.shutdown();

    let acc = 100.0 * correct.load(Ordering::Relaxed) as f64 / n as f64;
    println!("\naccuracy: {acc:.2}%  ({} / {} correct)", correct.load(Ordering::Relaxed), n);
    println!("wall time: {wall:.2} s  ({:.0} inf/s end-to-end)", n as f64 / wall);
    metrics.report().print(&arch);
    Ok(())
}
