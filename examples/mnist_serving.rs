//! End-to-end driver (EXPERIMENTS.md §E2E): serve the whole test split
//! through the sharded dynamic-batching coordinator, measuring accuracy,
//! wall-clock latency/throughput, and the simulated in-PCRAM cost per
//! request.  Runs hermetically on the SimBackend; with `make artifacts`
//! the real weights and the real synth-MNIST split are served (accuracy
//! is only meaningful then).
//!
//! ```bash
//! cargo run --release --example mnist_serving             # cnn1, auto shards
//! cargo run --release --example mnist_serving -- cnn2 4   # arch, shard count
//! cargo run --release --example mnist_serving -- cnn1 0 --net
//!                       # same workload through the L4 loopback TCP
//!                       # front-end (wire protocol + response cache)
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;
use odin::coordinator::{
    BatchPolicy, Engine, EnginePool, MetricsHub, ModelWeights, SYNTHETIC_SEED,
};
use odin::dataset::TestSet;
use odin::frontend::{Frontend, FrontendConfig, NetClient};

// Enough concurrent clients to keep several engine batches in flight —
// fewer in-flight requests than one batch (32) would serialize the
// shards and hide the pool's parallelism.
const CLIENT_THREADS: usize = 64;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let net = args.iter().any(|a| a == "--net");
    let args: Vec<String> = args.into_iter().filter(|a| a != "--net").collect();
    let arch = args.get(1).cloned().unwrap_or_else(|| "cnn1".into());
    let shards: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0);

    let metrics = MetricsHub::new();
    let weights = ModelWeights::load_or_synthetic("artifacts", &arch, SYNTHETIC_SEED)?;
    // Split the cores between shards and each shard's row-parallelism so
    // an auto-sized pool never oversubscribes the host.
    let threads = EnginePool::threads_per_shard(shards);
    let (pool, client) = EnginePool::spawn(
        move |_shard| Engine::sim_from_weights_threads(&weights, "fast", threads),
        shards, // 0 = one shard per core
        BatchPolicy::default(),
        metrics.clone(),
    )?;

    let test = Arc::new(TestSet::load_or_synthetic("artifacts", 2048, SYNTHETIC_SEED)?);
    let n = test.len();
    let transport = if net { "loopback TCP" } else { "in-process" };
    println!(
        "serving {n} requests for {arch}/fast [sim, {transport}] on {} shard(s) from {CLIENT_THREADS} client threads ...",
        pool.shards()
    );

    // With --net the same workload flows through the L4 front-end: each
    // client thread owns one TCP connection and the wire protocol, and a
    // response cache absorbs repeated rows.
    let frontend = if net {
        Some(Frontend::spawn(
            "127.0.0.1:0",
            client.clone(),
            &arch,
            "fast",
            FrontendConfig { cache_capacity: 4096, ..FrontendConfig::default() },
            metrics.clone(),
        )?)
    } else {
        None
    };
    let addr = frontend.as_ref().map(|f| f.local_addr());

    let correct = Arc::new(AtomicUsize::new(0));
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for t in 0..CLIENT_THREADS {
        let client = client.clone();
        let test = Arc::clone(&test);
        let correct = Arc::clone(&correct);
        let arch = arch.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let net_client =
                addr.map(|a| NetClient::connect(a, &arch, "fast")).transpose()?;
            for i in (t..test.len()).step_by(CLIENT_THREADS) {
                let s = &test.samples[i];
                let predicted = match &net_client {
                    Some(nc) => nc.infer(s.image.clone()).ok().map(|r| r.argmax),
                    None => {
                        client.infer_blocking(s.image.clone()).ok().map(|r| r.prediction.argmax)
                    }
                };
                if predicted == Some(s.label) {
                    correct.fetch_add(1, Ordering::Relaxed);
                }
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().unwrap()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    if let Some(f) = frontend {
        f.shutdown();
    }
    drop(client); // release the request channel so the dispatcher exits
    pool.shutdown();

    let acc = 100.0 * correct.load(Ordering::Relaxed) as f64 / n as f64;
    println!("\naccuracy: {acc:.2}%  ({} / {} correct)", correct.load(Ordering::Relaxed), n);
    println!("wall time: {wall:.2} s  ({:.0} inf/s end-to-end)", n as f64 / wall);
    metrics.report().print(&arch);
    Ok(())
}
