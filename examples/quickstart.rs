//! Quickstart: load an AOT-compiled stochastic CNN, run one inference,
//! and inspect the simulated in-PCRAM cost.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use odin::coordinator::Engine;
use odin::dataset::TestSet;
use odin::runtime::{Manifest, Runtime};
use odin::util::{fmt_ns, fmt_pj};

fn main() -> Result<()> {
    // 1. PJRT CPU client + artifact registry
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let manifest = Manifest::load("artifacts")?;

    // 2. Compile the optimized stochastic CNN1 variants and bind weights
    //    (weight streams are encoded in Rust — see coordinator::weights)
    let engine = Engine::new(&rt, &manifest, "artifacts", "cnn1", "fast")?;
    println!("compiled batch variants: {:?}", engine.batch_sizes());

    // 3. One real test image through the stochastic pipeline
    let test = TestSet::load("artifacts")?;
    let sample = &test.samples[0];
    let (preds, exec) = engine.infer(&[&sample.image])?;
    println!(
        "label {} -> predicted {} (logits[pred] = {:.2})",
        sample.label, preds[0].argmax, preds[0].logits[preds[0].argmax as usize]
    );
    println!("wall-clock exec: {}", fmt_ns(exec.exec_ns as f64));

    // 4. What the same inference costs inside ODIN's PCRAM banks
    let (sim_ns, sim_pj) = engine.sim_cost_per_inference();
    println!("simulated ODIN cost: {} / {}", fmt_ns(sim_ns), fmt_pj(sim_pj));
    Ok(())
}
