//! Quickstart: build the stochastic CNN on the pure-Rust SimBackend, run
//! one inference, and inspect the simulated in-PCRAM cost.  Fully
//! hermetic: real weights and the real test split are used when
//! `artifacts/` exists (after `make artifacts`), deterministic synthetic
//! stand-ins otherwise.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use odin::coordinator::{Engine, SYNTHETIC_SEED};
use odin::dataset::TestSet;
use odin::util::{fmt_ns, fmt_pj};

fn main() -> Result<()> {
    // 1. The optimized stochastic CNN1 on the sim backend (weight streams
    //    and the CNT16 table are built in Rust — see runtime::sim)
    let engine = Engine::sim_auto("artifacts", "cnn1", "fast")?;
    println!("backend: sim; batch variants: {:?}", engine.batch_sizes());

    // 2. One test image through the stochastic pipeline
    let test = TestSet::load_or_synthetic("artifacts", 64, SYNTHETIC_SEED)?;
    let sample = &test.samples[0];
    let (preds, exec) = engine.infer(&[&sample.image])?;
    println!(
        "label {} -> predicted {} (logits[pred] = {:.2})",
        sample.label, preds[0].argmax, preds[0].logits[preds[0].argmax as usize]
    );
    println!("wall-clock exec: {}", fmt_ns(exec.exec_ns as f64));

    // 3. What the same inference costs inside ODIN's PCRAM banks
    let (sim_ns, sim_pj) = engine.sim_cost_per_inference();
    println!("simulated ODIN cost: {} / {}", fmt_ns(sim_ns), fmt_pj(sim_pj));
    Ok(())
}
