//! Regenerate every table and figure of the paper in one run (the
//! analytic parts; accuracy columns come from `odin table2` / the
//! mnist_serving example, which need the PJRT path).
//!
//! ```bash
//! cargo run --release --example paper_tables
//! ```

use odin::harness::{fig6, headline, table1, table2, table3};
use odin::mapper::ExecConfig;
use odin::pim::AccumulateMode;

fn main() {
    println!("=== ODIN paper reproduction: all tables & figures ===\n");
    table1(true);
    // Table 2 counts under both accumulation modes
    for mode in [AccumulateMode::Binary, AccumulateMode::Mux] {
        let cfg = ExecConfig { mode, ..ExecConfig::paper() };
        table2(&cfg, &[], true);
    }
    table3(true);
    fig6(&ExecConfig::paper(), true);
    println!("=== headline claims (paper-calibrated profile) ===");
    headline(&ExecConfig::paper(), true);
    println!("=== same grid under the datasheet profile (see EXPERIMENTS.md) ===");
    headline(&ExecConfig::default(), true);
}
