//! Design-space exploration: the ablations DESIGN.md §5 calls out.
//!
//! 1. Accumulation mode (binary vs paper's MUX tree): command cost vs
//!    stochastic MAC error — the repo's central accuracy/cost trade.
//! 2. Concurrency scaling (banks x partitions): where bank-level
//!    parallelism stops paying.
//! 3. Conv amortization sensitivity: strict per-product accounting vs the
//!    paper-implied row-parallel flow.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use odin::ann::topology::{cnn1, vgg1};
use odin::mapper::{map_topology, ExecConfig};
use odin::pim::AccumulateMode;
use odin::stochastic::encode::rails;
use odin::stochastic::mac::{mac_binary, mac_mux};
use odin::util::rng::Rng;
use odin::util::{fmt_ns, fmt_pj};

fn main() {
    println!("== ablation 1: accumulation mode ==");
    println!("{:<8} {:<6} {:>14} {:>14} {:>14}", "mode", "net", "latency", "energy", "commands");
    for mode in [AccumulateMode::Binary, AccumulateMode::Mux] {
        for topo in [cnn1(), vgg1()] {
            let cfg = ExecConfig { mode, ..ExecConfig::paper() };
            let cost = map_topology(&topo, &cfg);
            println!(
                "{:<8} {:<6} {:>14} {:>14} {:>14}",
                format!("{mode:?}"),
                topo.name,
                fmt_ns(cost.latency_ns(&cfg)),
                fmt_pj(cost.energy_pj()),
                cost.total_ledger().total_commands()
            );
        }
    }

    println!("\n   MAC error vs exact (one 784-input FC layer, 16 trials):");
    let mut rng = Rng::new(17);
    let n = 784;
    let (mut eb, mut em, mut scale) = (0.0, 0.0, 0.0);
    for _ in 0..16 {
        let a: Vec<u8> = (0..n).map(|_| rng.u8() / 2).collect();
        let wq: Vec<i16> = (0..n).map(|_| rng.range_i32(-200, 200) as i16).collect();
        let (wp, wn) = rails(&wq);
        let exact: f64 = a.iter().zip(&wq).map(|(&x, &w)| x as f64 * w as f64).sum();
        eb += (mac_binary(&a, &wp, &wn) as f64 * 256.0 - exact).abs();
        em += (mac_mux(&a, &wp, &wn) as f64 * 65536.0 - exact).abs();
        scale += exact.abs();
    }
    println!("   binary: {:.2}% relative   mux: {:.2}% relative", 100.0 * eb / scale, 100.0 * em / scale);

    println!("\n== ablation 2: concurrency scaling (CNN1 latency) ==");
    for banks in [1usize, 8, 32, 128] {
        for parts in [1usize, 15] {
            let cfg = ExecConfig {
                parallel_banks: banks,
                partition_parallelism: parts,
                ..ExecConfig::paper()
            };
            let cost = map_topology(&cnn1(), &cfg);
            println!(
                "   banks {banks:>4} x partitions {parts:>2} -> {:>12}",
                fmt_ns(cost.latency_ns(&cfg))
            );
        }
    }

    println!("\n== ablation 3: conv amortization (VGG1) ==");
    for amort in [1u64, 32, 256] {
        let cfg = ExecConfig { conv_amortization: amort, ..ExecConfig::paper() };
        let cost = map_topology(&vgg1(), &cfg);
        println!(
            "   amortization {amort:>4} -> latency {:>12}  energy {:>12}",
            fmt_ns(cost.latency_ns(&cfg)),
            fmt_pj(cost.energy_pj())
        );
    }
}
