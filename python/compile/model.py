"""L2: quantized ANN forward passes built on the L1 stochastic-MAC kernel.

This is the compute graph ODIN executes: per layer —

  binary u8 activations --B_TO_S--> SN streams --ANN_MUL/ANN_ACC--> SN MAC
  --S_TO_B(popcount)--> binary --rescale + bias + ReLU (CMOS block)-->
  requantized u8 activations --> next layer

Max pooling runs in the binary domain on u8 values (the paper's 4:1 pooling
logic block).  Everything here is traced once by ``aot.py`` and lowered to
HLO text; at serve time the Rust coordinator feeds images + weight tensors
as PJRT literals.

Three forward variants per network:
  * ``sc``    — faithful bit-parallel emulation (Pallas kernel ``sc_mac``);
  * ``fast``  — algebraically-reduced stochastic path (bit-identical
                outputs, one dot_general per layer) — the optimized artifact;
  * ``float`` — f32 reference network (baseline + accuracy-delta oracle).

Architectures (see DESIGN.md §8 for the MLBench string interpretation):
  CNN1: conv5x5(4 maps, same) - pool2 - fc 784-70 - fc 70-10   (MNIST-like)
  CNN2: conv7x7(10 maps, valid) - pool2 - fc 1210-120 - fc 120-10
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import sc_mac as K
from .kernels.sc_common import LANES, STREAM_BITS

# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

ARCHS = {
    # conv: kernel size, output maps, padding; fc: list of (in, out)
    "cnn1": dict(in_hw=28, k=5, maps=4, pad="same", pool=2,
                 fc=[(784, 70), (70, 10)]),
    "cnn2": dict(in_hw=28, k=7, maps=10, pad="valid", pool=2,
                 fc=[(1210, 120), (120, 10)]),
}


def conv_out_hw(arch: dict) -> int:
    """Spatial size after the conv layer (before pooling)."""
    return arch["in_hw"] if arch["pad"] == "same" else arch["in_hw"] - arch["k"] + 1





# ---------------------------------------------------------------------------
# Shared graph pieces
# ---------------------------------------------------------------------------

def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def im2col(img: jnp.ndarray, k: int, pad: str) -> jnp.ndarray:
    """(B, H, W) -> (B, P, k*k) patch matrix, static shapes only."""
    b, h, w = img.shape
    if pad == "same":
        p = k // 2
        img = jnp.pad(img, ((0, 0), (p, p), (p, p)))
        oh, ow = h, w
    else:
        oh, ow = h - k + 1, w - k + 1
    cols = []
    for dy in range(k):
        for dx in range(k):
            cols.append(img[:, dy:dy + oh, dx:dx + ow])
    patches = jnp.stack(cols, axis=-1)  # (B, oh, ow, k*k)
    return patches.reshape(b, oh * ow, k * k)


def _sc_matmul(a_u8: jnp.ndarray, w_args: tuple, n: int, m: int, fast: bool) -> jnp.ndarray:
    """Stochastic MAC of (R, n) u8 activations against m neurons.

    ``w_args`` is (wpos_packed, wneg_packed) u32 (m, n, LANES) for the
    faithful path, or (wpos_q, wneg_q) u8 (m, n) for the fast path.
    Returns raw popcount differences (R, m) i32.  The fast path needs no
    padding (pure gather); the faithful Pallas path pads rows to TB and
    neurons to TM in-graph — zero padding is exact (encode(0) = all-zeros).
    """
    wa, wb = w_args
    if fast:
        return K.sc_mac_fast(a_u8, wa, wb)
    r = a_u8.shape[0]
    rp = _round_up(r, K.TB)
    mp = _round_up(m, K.TM)
    if rp != r:
        a_u8 = jnp.pad(a_u8, ((0, rp - r), (0, 0)))
    if mp != m:
        wa = jnp.pad(wa, ((0, mp - m), (0, 0), (0, 0)))
        wb = jnp.pad(wb, ((0, mp - m), (0, 0), (0, 0)))
    raw = K.sc_mac(a_u8, wa, wb)
    return raw[:r, :m]


def _rescale(raw: jnp.ndarray, bias: jnp.ndarray, n: int, s_a: float, s_w: float,
             s_out) -> jnp.ndarray:
    """Binary-domain epilogue: rescale raw popcounts to f32, add bias, ReLU +
    requantize to u8 if ``s_out`` is given (hidden layer), else return f32
    logits (output layer).  E[raw] = sum(a*w) / 256 (binary accumulation),
    so the rescale factor is 256 * s_a * s_w."""
    y = raw.astype(jnp.float32) * jnp.float32(256.0 * s_a * s_w) + bias
    if s_out is None:
        return y
    y = jnp.maximum(y, 0.0)  # 8-bit ReLU block
    return jnp.clip(jnp.round(y / jnp.float32(s_out)), 0, 255).astype(jnp.uint8)


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """(B, H, W, F) -> (B, H/2, W/2, F) 4:1 max pooling (binary domain)."""
    b, h, w, f = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, f)
    return x.max(axis=(2, 4))


# ---------------------------------------------------------------------------
# Stochastic forward (faithful and fast share structure)
# ---------------------------------------------------------------------------

def make_sc_fwd(arch_name: str, scales: dict, fast: bool):
    """Build fwd(img_u8, conv_wp, conv_wn, conv_b, fc1_wp, fc1_wn, fc1_b,
    fc2_wp, fc2_wn, fc2_b) -> logits f32 (batch, 10).

    ``scales``: {"s_in", "conv": {"s_w","s_out"}, "fc1": {...}, "fc2": {"s_w"}}.
    Weight tensors are runtime args so the Rust coordinator owns them.
    """
    arch = ARCHS[arch_name]
    k, maps, pool = arch["k"], arch["maps"], arch["pool"]
    n_conv = k * k
    ohw = conv_out_hw(arch)
    phw = ohw // pool
    (n1, m1), (n2, m2) = arch["fc"]
    assert phw * phw * maps == n1, (arch_name, phw, maps, n1)

    s_in = scales["s_in"]
    sc_, s1, s2 = scales["conv"], scales["fc1"], scales["fc2"]

    def fwd_core(img, conv_wp, conv_wn, conv_b, fc1_wp, fc1_wn, fc1_b,
                 fc2_wp, fc2_wn, fc2_b):
        b = img.shape[0]
        # conv layer as im2col + stochastic MAC
        patches = im2col(img, k, arch["pad"])  # (B, P, k*k) u8
        rows = patches.reshape(b * patches.shape[1], n_conv)
        raw = _sc_matmul(rows, (conv_wp, conv_wn), n_conv, maps, fast)
        act = _rescale(raw, conv_b, n_conv, s_in, sc_["s_w"], sc_["s_out"])
        act = act.reshape(b, ohw, ohw, maps)
        act = maxpool2(act)  # (B, phw, phw, maps) u8
        flat = act.reshape(b, n1)
        # fc1
        raw = _sc_matmul(flat, (fc1_wp, fc1_wn), n1, m1, fast)
        h = _rescale(raw, fc1_b, n1, sc_["s_out"], s1["s_w"], s1["s_out"])
        # fc2 (logits, stay f32)
        raw = _sc_matmul(h, (fc2_wp, fc2_wn), n2, m2, fast)
        return (_rescale(raw, fc2_b, n2, s1["s_out"], s2["s_w"], None),)

    return fwd_core


def sc_weight_arg_shapes(arch_name: str, fast: bool, batch: int):
    """ShapeDtypeStructs for jax.jit(...).lower — must match what the Rust
    runtime feeds (see rust/src/coordinator/weights.rs)."""
    arch = ARCHS[arch_name]
    k, maps = arch["k"], arch["maps"]
    (n1, m1), (n2, m2) = arch["fc"]
    u8, u32, f32 = jnp.uint8, jnp.uint32, jnp.float32

    def w(m, n):
        if fast:
            return jax.ShapeDtypeStruct((m, n), u8)
        return jax.ShapeDtypeStruct((m, n, LANES), u32)

    img = jax.ShapeDtypeStruct((batch, arch["in_hw"], arch["in_hw"]), u8)
    f = jax.ShapeDtypeStruct
    return (
        img,
        w(maps, k * k), w(maps, k * k), f((maps,), f32),
        w(m1, n1), w(m1, n1), f((m1,), f32),
        w(m2, n2), w(m2, n2), f((m2,), f32),
    )


# ---------------------------------------------------------------------------
# Float reference network (same topology, f32)
# ---------------------------------------------------------------------------

def make_float_fwd(arch_name: str):
    """fwd(img f32 (B,H,W) in [0,1], conv_w (k*k, maps), conv_b, fc1_w (n1,m1),
    fc1_b, fc2_w (n2,m2), fc2_b) -> logits (B, 10)."""
    arch = ARCHS[arch_name]
    k, maps = arch["k"], arch["maps"]
    ohw = conv_out_hw(arch)
    (n1, m1), (n2, m2) = arch["fc"]

    def fwd(img, conv_w, conv_b, fc1_w, fc1_b, fc2_w, fc2_b):
        b = img.shape[0]
        patches = im2col(img, k, arch["pad"])  # (B, P, k*k) f32
        y = patches.reshape(-1, k * k) @ conv_w + conv_b
        y = jnp.maximum(y, 0.0).reshape(b, ohw, ohw, maps)
        y = maxpool2(y).reshape(b, n1)
        h = jnp.maximum(y @ fc1_w + fc1_b, 0.0)
        return (h @ fc2_w + fc2_b,)

    return fwd


def float_weight_arg_shapes(arch_name: str, batch: int):
    arch = ARCHS[arch_name]
    k, maps = arch["k"], arch["maps"]
    (n1, m1), (n2, m2) = arch["fc"]
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    return (
        s((batch, arch["in_hw"], arch["in_hw"]), f32),
        s((k * k, maps), f32), s((maps,), f32),
        s((n1, m1), f32), s((m1,), f32),
        s((n2, m2), f32), s((m2,), f32),
    )


# ---------------------------------------------------------------------------
# Quantization helpers (used by train.py and tests)
# ---------------------------------------------------------------------------

def quantize_weights(w: np.ndarray):
    """f32 weights -> (q i16, s_w) with q = round(w / s_w) in [-255, 255]."""
    s_w = float(np.abs(w).max()) / 255.0
    if s_w == 0.0:
        s_w = 1.0 / 255.0
    q = np.clip(np.round(w / s_w), -255, 255).astype(np.int16)
    return q, s_w


def rails(q: np.ndarray):
    """Signed q -> unipolar dual-rail (wpos, wneg) u8."""
    return (np.clip(q, 0, 255).astype(np.uint8),
            np.clip(-q, 0, 255).astype(np.uint8))


def weight_values(w_rail: np.ndarray) -> np.ndarray:
    """(n, m) u8 rail -> (m, n) u8 layout the kernels expect."""
    return np.ascontiguousarray(w_rail.T)
