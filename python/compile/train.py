"""Build-time training of the CNN1/CNN2 benchmark topologies on synth-MNIST.

Runs once as part of ``make artifacts`` (cached on the weight files).  Plain
JAX with a hand-rolled Adam — no optax dependency.  Produces, per arch:

  artifacts/weights/<arch>.bin   — float weights, quantized rails, scales
                                   (tensorfile TLV, parsed by Rust)
  artifacts/weights/<arch>.json  — human-readable meta (scales, accuracy)
  artifacts/data/test.bin        — the shared 2048-sample test split

Quantization follows model.py: symmetric per-tensor weight scales
(q in [-255, 255], dual-rail u8), activation scales from a 1024-sample
max calibration pass.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .dataset import train_test_split
from .tensorfile import write_tensors

STEPS = 700
BATCH = 128
LR = 1e-3


def init_params(arch_name: str, seed: int = 0) -> dict:
    arch = M.ARCHS[arch_name]
    k, maps = arch["k"], arch["maps"]
    (n1, m1), (n2, m2) = arch["fc"]
    rng = np.random.default_rng(seed)

    def glorot(nin, nout):
        lim = np.sqrt(6.0 / (nin + nout))
        return rng.uniform(-lim, lim, (nin, nout)).astype(np.float32)

    return {
        "conv_w": glorot(k * k, maps), "conv_b": np.zeros(maps, np.float32),
        "fc1_w": glorot(n1, m1), "fc1_b": np.zeros(m1, np.float32),
        "fc2_w": glorot(n2, m2), "fc2_b": np.zeros(m2, np.float32),
    }


def _loss_fn(fwd, params, x, y):
    (logits,) = fwd(x, params["conv_w"], params["conv_b"], params["fc1_w"],
                    params["fc1_b"], params["fc2_w"], params["fc2_b"])
    logz = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logz, y[:, None], axis=1).mean()


def train(arch_name: str, data, seed: int = 0, steps: int = STEPS):
    """Returns (params, float test accuracy)."""
    (xtr, ytr), (xte, yte) = data
    fwd = M.make_float_fwd(arch_name)
    params = {k: jnp.asarray(v) for k, v in init_params(arch_name, seed).items()}
    mom = {k: jnp.zeros_like(v) for k, v in params.items()}
    vel = {k: jnp.zeros_like(v) for k, v in params.items()}

    @jax.jit
    def step(params, mom, vel, x, y, t):
        loss, grads = jax.value_and_grad(lambda p: _loss_fn(fwd, p, x, y))(params)
        b1, b2, eps = 0.9, 0.999, 1e-8
        new_p, new_m, new_v = {}, {}, {}
        for k in params:
            new_m[k] = b1 * mom[k] + (1 - b1) * grads[k]
            new_v[k] = b2 * vel[k] + (1 - b2) * grads[k] ** 2
            mhat = new_m[k] / (1 - b1 ** t)
            vhat = new_v[k] / (1 - b2 ** t)
            new_p[k] = params[k] - LR * mhat / (jnp.sqrt(vhat) + eps)
        return new_p, new_m, new_v, loss

    rng = np.random.default_rng(seed + 100)
    xtr_f = xtr.astype(np.float32) / 255.0
    for t in range(1, steps + 1):
        idx = rng.integers(0, len(xtr), BATCH)
        params, mom, vel, loss = step(
            params, mom, vel, jnp.asarray(xtr_f[idx]), jnp.asarray(ytr[idx]), t)
        if t % 100 == 0:
            print(f"  [{arch_name}] step {t:4d} loss {float(loss):.4f}")

    acc = evaluate_float(arch_name, params, xte, yte)
    return {k: np.asarray(v) for k, v in params.items()}, acc


def evaluate_float(arch_name: str, params, xte, yte, batch: int = 256) -> float:
    fwd = jax.jit(M.make_float_fwd(arch_name))
    correct = 0
    for i in range(0, len(xte), batch):
        x = jnp.asarray(xte[i:i + batch].astype(np.float32) / 255.0)
        (logits,) = fwd(x, params["conv_w"], params["conv_b"], params["fc1_w"],
                        params["fc1_b"], params["fc2_w"], params["fc2_b"])
        correct += int((np.argmax(np.asarray(logits), 1) == yte[i:i + batch]).sum())
    return correct / len(xte)


def calibrate(arch_name: str, params, xcal: np.ndarray) -> dict:
    """Max-calibration of the two requantized activation tensors."""
    arch = M.ARCHS[arch_name]
    k, maps = arch["k"], arch["maps"]
    ohw = M.conv_out_hw(arch)
    (n1, m1), _ = arch["fc"]

    x = jnp.asarray(xcal.astype(np.float32) / 255.0)
    patches = M.im2col(x, k, arch["pad"])
    y = jnp.maximum(patches.reshape(-1, k * k) @ params["conv_w"] + params["conv_b"], 0.0)
    conv_max = float(y.max())
    y = M.maxpool2(y.reshape(len(xcal), ohw, ohw, maps)).reshape(len(xcal), n1)
    h = jnp.maximum(y @ params["fc1_w"] + params["fc1_b"], 0.0)
    fc1_max = float(h.max())
    return {"conv_out_max": conv_max, "fc1_out_max": fc1_max}


def quantize(arch_name: str, params, calib: dict) -> tuple[dict, dict]:
    """Returns (q tensors, scales dict) per model.py's scheme."""
    conv_q, s_w_conv = M.quantize_weights(params["conv_w"])
    fc1_q, s_w_fc1 = M.quantize_weights(params["fc1_w"])
    fc2_q, s_w_fc2 = M.quantize_weights(params["fc2_w"])
    scales = {
        "s_in": 1.0 / 255.0,
        "conv": {"s_w": s_w_conv, "s_out": calib["conv_out_max"] / 255.0},
        "fc1": {"s_w": s_w_fc1, "s_out": calib["fc1_out_max"] / 255.0},
        "fc2": {"s_w": s_w_fc2},
    }
    q = {"conv_q": conv_q, "fc1_q": fc1_q, "fc2_q": fc2_q}
    return q, scales


def export(arch_name: str, params, q, scales, acc_float: float, outdir: str) -> None:
    os.makedirs(outdir, exist_ok=True)
    flat_scales = np.array([
        scales["s_in"], scales["conv"]["s_w"], scales["conv"]["s_out"],
        scales["fc1"]["s_w"], scales["fc1"]["s_out"], scales["fc2"]["s_w"],
    ], dtype=np.float32)
    tensors = {
        "scales": flat_scales,
        "conv_b": params["conv_b"], "fc1_b": params["fc1_b"], "fc2_b": params["fc2_b"],
        "conv_w": params["conv_w"], "fc1_w": params["fc1_w"], "fc2_w": params["fc2_w"],
        **q,
    }
    write_tensors(os.path.join(outdir, f"{arch_name}.bin"), tensors)
    with open(os.path.join(outdir, f"{arch_name}.json"), "w") as f:
        json.dump({"arch": arch_name, "scales": scales,
                   "float_test_acc": acc_float}, f, indent=2)
    print(f"  [{arch_name}] float test acc {acc_float:.4f} -> {outdir}/{arch_name}.bin")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=STEPS)
    args = ap.parse_args()

    data = train_test_split()
    (xtr, ytr), (xte, yte) = data

    os.makedirs(os.path.join(args.out, "data"), exist_ok=True)
    write_tensors(os.path.join(args.out, "data", "test.bin"),
                  {"images": xte, "labels": yte})

    for arch in ("cnn1", "cnn2"):
        print(f"training {arch} ...")
        params, acc = train(arch, data, steps=args.steps)
        calib = calibrate(arch, params, xtr[:1024])
        q, scales = quantize(arch, params, calib)
        export(arch, params, q, scales, acc, os.path.join(args.out, "weights"))


if __name__ == "__main__":
    main()
