"""Synth-MNIST: a procedurally generated 28x28 10-class digit dataset.

The paper trains CNN1/CNN2 on MNIST.  MNIST itself is not available in this
offline environment (repro band 0), so we substitute a deterministic
procedural dataset that exercises the same code path: 28x28 grayscale digit
images with geometric jitter and additive noise, 10 classes.  See DESIGN.md
§2 for the substitution rationale — every read/write/energy count in the
evaluation is a pure function of topology, so only the accuracy column of
Table 2 depends on the data, and there the *claim structure* (stochastic
8-bit inference tracks float accuracy closely) is what we reproduce.
"""

from __future__ import annotations

import numpy as np

# 7x5 digit glyphs (classic bitmap font), one string row per pixel row.
_GLYPHS = {
    0: ["#####", "#...#", "#...#", "#...#", "#...#", "#...#", "#####"],
    1: ["..#..", ".##..", "..#..", "..#..", "..#..", "..#..", ".###."],
    2: ["#####", "....#", "....#", "#####", "#....", "#....", "#####"],
    3: ["#####", "....#", "....#", "#####", "....#", "....#", "#####"],
    4: ["#...#", "#...#", "#...#", "#####", "....#", "....#", "....#"],
    5: ["#####", "#....", "#....", "#####", "....#", "....#", "#####"],
    6: ["#####", "#....", "#....", "#####", "#...#", "#...#", "#####"],
    7: ["#####", "....#", "...#.", "..#..", ".#...", ".#...", ".#..."],
    8: ["#####", "#...#", "#...#", "#####", "#...#", "#...#", "#####"],
    9: ["#####", "#...#", "#...#", "#####", "....#", "....#", "#####"],
}


def _glyph_array(d: int) -> np.ndarray:
    return np.array([[c == "#" for c in row] for row in _GLYPHS[d]], dtype=np.float32)


def make_dataset(n: int, seed: int):
    """Generate (images u8 (n, 28, 28), labels u8 (n,)).

    Each sample: glyph upscaled 3x (21x15), random placement (the glyph
    always fits), per-sample intensity in [160, 255], Gaussian pixel noise
    sigma 18, occasional single-pixel dropout.  Deterministic given seed.
    ``train.py`` exports the test split to ``artifacts/data/`` so the Rust
    examples evaluate on the *identical* samples.
    """
    rng = np.random.default_rng(seed)
    imgs = np.zeros((n, 28, 28), dtype=np.float32)
    labels = rng.integers(0, 10, n).astype(np.uint8)
    glyphs = {d: np.kron(_glyph_array(d), np.ones((3, 3), np.float32)) for d in range(10)}
    gh, gw = 21, 15
    for i in range(n):
        g = glyphs[int(labels[i])].copy()
        # stroke erosion: knock out a few glyph pixels entirely
        for _ in range(rng.integers(2, 9)):
            g[rng.integers(0, gh), rng.integers(0, gw)] = 0.0
        oy = rng.integers(0, 28 - gh + 1)
        ox = rng.integers(0, 28 - gw + 1)
        inten = rng.uniform(90, 255)
        imgs[i, oy:oy + gh, ox:ox + gw] = g * inten
        # distractor strokes: short random bright segments
        for _ in range(rng.integers(1, 4)):
            y0, x0 = rng.integers(0, 28, 2)
            dy, dx = rng.integers(-1, 2, 2)
            for t in range(rng.integers(3, 8)):
                yy, xx = y0 + dy * t, x0 + dx * t
                if 0 <= yy < 28 and 0 <= xx < 28:
                    imgs[i, yy, xx] = rng.uniform(80, 220)
        imgs[i] += rng.normal(0, 35, (28, 28))
        # random pixel dropout, emulating sensor defects
        for _ in range(rng.integers(0, 6)):
            imgs[i, rng.integers(0, 28), rng.integers(0, 28)] = 0.0
    return np.clip(imgs, 0, 255).astype(np.uint8), labels


def train_test_split(n_train: int = 8192, n_test: int = 2048, seed: int = 7):
    """The canonical splits used by train.py, tests, and the Rust examples."""
    xtr, ytr = make_dataset(n_train, seed)
    xte, yte = make_dataset(n_test, seed + 1)
    return (xtr, ytr), (xte, yte)
