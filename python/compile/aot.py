"""AOT lowering: JAX/Pallas compute graphs -> HLO text artifacts.

Python's last act: every forward variant is traced once, lowered to
StableHLO, converted to an XlaComputation, and dumped as **HLO text** into
``artifacts/``.  The Rust runtime (rust/src/runtime/) loads the text with
``HloModuleProto::from_text_file``, compiles it on the PJRT CPU client, and
executes it with request data — Python never runs on the request path.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Artifacts (per arch x mode x batch, see ``manifest.json``):
  {arch}_fast_b{B}.hlo.txt   optimized stochastic path (table gather)
  {arch}_sc_b{B}.hlo.txt     faithful bit-parallel Pallas emulation
  {arch}_float_b{B}.hlo.txt  f32 reference network
  sc_tile.hlo.txt            bare faithful MAC tile (kernel microbench)
  sc_tile_fast.hlo.txt       bare optimized MAC tile
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import sc_mac as K
from .kernels.sc_common import LANES

FAST_BATCHES = (1, 8, 32)
SC_BATCHES = (1,)
FLOAT_BATCHES = (1, 32)

# Generic MAC tile dimensions (kernel microbenchmark artifact).
TILE_B, TILE_M, TILE_N = K.TB, K.TM, 256


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default ELIDES big literals as "{...}",
    # which xla_extension 0.5.1's text parser silently turns into garbage
    # buffers — the LUT tables must be printed in full.
    return comp.as_hlo_text(print_large_constants=True)


def _spec_list(args) -> list[dict]:
    return [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in args]


def lower_model(arch: str, mode: str, batch: int, scales: dict) -> tuple[str, list[dict]]:
    if mode == "float":
        fwd = M.make_float_fwd(arch)
        args = M.float_weight_arg_shapes(arch, batch)
    else:
        fwd = M.make_sc_fwd(arch, scales, fast=(mode == "fast"))
        args = M.sc_weight_arg_shapes(arch, fast=(mode == "fast"), batch=batch)
    lowered = jax.jit(fwd).lower(*args)
    return to_hlo_text(lowered), _spec_list(args)


def lower_tile(fast: bool) -> tuple[str, list[dict]]:
    if fast:
        args = (
            jax.ShapeDtypeStruct((TILE_B, TILE_N), jnp.uint8),
            jax.ShapeDtypeStruct((TILE_M, TILE_N), jnp.uint8),
            jax.ShapeDtypeStruct((TILE_M, TILE_N), jnp.uint8),
        )
        fn = lambda a, wp, wn: (K.sc_mac_fast(a, wp, wn),)
    else:
        args = (
            jax.ShapeDtypeStruct((TILE_B, TILE_N), jnp.uint8),
            jax.ShapeDtypeStruct((TILE_M, TILE_N, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((TILE_M, TILE_N, LANES), jnp.uint32),
        )
        fn = lambda a, wp, wn: (K.sc_mac(a, wp, wn),)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered), _spec_list(args)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest: dict[str, dict] = {}

    def emit(name: str, text: str, meta: dict) -> None:
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = meta
        print(f"  {name}.hlo.txt  ({len(text) // 1024} KiB)")

    for arch in ("cnn1", "cnn2"):
        with open(os.path.join(args.out, "weights", f"{arch}.json")) as f:
            scales = json.load(f)["scales"]
        for mode, batches in (("fast", FAST_BATCHES), ("sc", SC_BATCHES),
                              ("float", FLOAT_BATCHES)):
            for b in batches:
                text, specs = lower_model(arch, mode, b, scales)
                emit(f"{arch}_{mode}_b{b}", text,
                     {"kind": "model", "arch": arch, "mode": mode,
                      "batch": b, "args": specs})

    for fast in (False, True):
        name = "sc_tile_fast" if fast else "sc_tile"
        text, specs = lower_tile(fast)
        emit(name, text, {"kind": "tile", "mode": "fast" if fast else "sc",
                          "args": specs})

    write_golden(args.out)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest)} artifacts + manifest.json")


def write_golden(outdir: str) -> None:
    """Cross-language golden vectors: the Rust stochastic/ module must
    reproduce these bit-for-bit (rust/src/stochastic/golden.rs)."""
    import numpy as np
    from .kernels import ref as REF
    from .kernels.sc_common import T_WGT, wgt_thresholds
    from .tensorfile import write_tensors

    rng = np.random.default_rng(2024)
    a = rng.integers(0, 256, (8, 100), dtype=np.uint8)
    wq = rng.integers(-255, 256, (32, 100)).astype(np.int16)
    wp = np.clip(wq, 0, 255).astype(np.uint8)
    wn = np.clip(-wq, 0, 255).astype(np.uint8)
    write_tensors(os.path.join(outdir, "golden.bin"), {
        "a": a, "wq": wq,
        "raw": REF.sc_mac_ref(a, wp, wn),
        "wp_streams": REF.encode_weights(wp),
        "t_wgt": T_WGT.astype(np.uint8),
        "t_wgt_d3": wgt_thresholds(3).astype(np.uint8),
        "cnt16": REF.cnt16_table_np(),
    })
    print("  golden.bin (cross-language vectors)")


if __name__ == "__main__":
    main()
