"""Pure-numpy correctness oracles for the stochastic MAC kernels.

For each accumulation mode (binary = default, mux = paper-faithful ablation)
three independent references must agree:

1. ``sc_mac_ref`` / ``sc_mac_mux_ref``  — full bitwise emulation (encode /
   AND / accumulate / popcount) in plain numpy.  Must be **bit-exact**
   against the Pallas kernels.
2. ``sc_mac_table`` / ``sc_mac_mux_diagonal``  — algebraic closed forms.
   Bit-exactness against (1) *is* the proof that the optimized serve path
   (``sc_mac.sc_mac_fast``) computes the same thing as the hardware
   emulation.
3. ``float_mac``  — the real-valued MAC the stochastic pipeline
   approximates; used for statistical-accuracy tests (SC error bounds),
   not exact equality.
"""

from __future__ import annotations

import math

import numpy as np

from .sc_common import (
    LANES,
    N_ROT,
    ROT_STRIDE,
    STREAM_BITS,
    T_ACT,
    T_WGT,
    encode_np,
    mux_select_masks,
    pack_bits_u32,
    rot_amount,
    wgt_thresholds,
)

_S_MASKS = mux_select_masks()  # (8, LANES) uint32


def popcount_u32(v: np.ndarray) -> np.ndarray:
    """SWAR popcount, identical structure to the kernel's."""
    v = v.astype(np.uint32)
    v = v - ((v >> 1) & np.uint32(0x55555555))
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    return (v * np.uint32(0x01010101)) >> 24


# ---------------------------------------------------------------------------
# Binary accumulation mode
# ---------------------------------------------------------------------------

def encode_weights(w_q: np.ndarray) -> np.ndarray:
    """Encode u8 weight values (M, N) into packed, per-operand-rotated
    streams (M, N, LANES) uint32 — the load-time step the Rust coordinator
    performs (B_TO_S for weights + rotated row write)."""
    m, n = w_q.shape
    bits = (T_WGT[None, None, :] < w_q[..., None]).astype(np.uint8)  # (M, N, 256)
    for j in range(n):
        r = rot_amount(j)
        if r:
            # rotated stream: bit i = (T_WGT[(i + r) % 256] < w)
            bits[:, j] = np.roll(bits[:, j], -r, axis=-1)
    return pack_bits_u32(bits)


def sc_mac_ref(a_vals: np.ndarray, wpos_q: np.ndarray, wneg_q: np.ndarray) -> np.ndarray:
    """Full bitwise oracle, binary mode.  a_vals (B, N) u8; w*_q (M, N) u8.

    Returns (B, M) int32 raw popcount difference.
    """
    B, N = a_vals.shape
    M = wpos_q.shape[0]
    a_str = encode_np(a_vals.reshape(-1), T_ACT).reshape(B, N, LANES)
    wpos = encode_weights(wpos_q)
    wneg = encode_weights(wneg_q)
    out = np.zeros((B, M), dtype=np.int64)
    for b in range(B):
        p_pos = a_str[b, None] & wpos  # (M, N, LANES)
        p_neg = a_str[b, None] & wneg
        pc_pos = popcount_u32(p_pos).astype(np.int64).sum(axis=(-1, -2))
        pc_neg = popcount_u32(p_neg).astype(np.int64).sum(axis=(-1, -2))
        out[b] = pc_pos - pc_neg
    return out.astype(np.int32)


def cnt16_table_np() -> np.ndarray:
    """(N_ROT, 256, 256) i32: CNT[r, a, w] = popcount(enc(a) & rot_r(enc(w)))."""
    ii = np.arange(STREAM_BITS)
    abit = (ii[None, :] < ii[:, None]).astype(np.int32)  # (a, i)
    out = np.zeros((N_ROT, 256, 256), np.int32)
    for r in range(N_ROT):
        tw = T_WGT[(ii + ROT_STRIDE * r) % STREAM_BITS]
        wbit = (tw[None, :] < ii[:, None]).astype(np.int32)  # (w, i)
        out[r] = abit @ wbit.T
    return out


_CNT16 = None


def sc_mac_table(a_vals: np.ndarray, wpos_q: np.ndarray, wneg_q: np.ndarray) -> np.ndarray:
    """Closed-form oracle, binary mode: per-product popcount table gather."""
    global _CNT16
    if _CNT16 is None:
        _CNT16 = cnt16_table_np()
    B, N = a_vals.shape
    r = (np.arange(N) % N_ROT)
    a = a_vals.astype(np.int64)
    cp = _CNT16[r[None, None, :], a[:, None, :], wpos_q.astype(np.int64)[None, :, :]]
    cn = _CNT16[r[None, None, :], a[:, None, :], wneg_q.astype(np.int64)[None, :, :]]
    return (cp.astype(np.int64) - cn).sum(-1).astype(np.int32)


def float_mac(a_vals: np.ndarray, wpos_q: np.ndarray, wneg_q: np.ndarray) -> np.ndarray:
    """Expected value of the binary-mode raw output: sum_j a_j * w_j / 256."""
    a = a_vals.astype(np.float64)
    w = wpos_q.astype(np.float64) - wneg_q.astype(np.float64)
    return a @ w.T / 256.0


# ---------------------------------------------------------------------------
# MUX-tree accumulation mode (ablation)
# ---------------------------------------------------------------------------

def encode_weights_mux(w_q: np.ndarray, depth: int) -> np.ndarray:
    """Encode u8 weight values (M, C, NL) into packed streams
    (M, C, NL, LANES) uint32 against the depth-specific LUT."""
    t = wgt_thresholds(depth)
    return encode_np(w_q.reshape(-1), t).reshape(*w_q.shape, LANES)


def mux_tree_np(products: np.ndarray, depth: int) -> np.ndarray:
    """Depth-D MUX tree over axis -2 (NL streams), packed uint32."""
    acc = products
    for k in range(depth):
        s = _S_MASKS[k].astype(np.uint32)
        ns = s ^ np.uint32(0xFFFFFFFF)
        acc = (s & acc[..., 1::2, :]) | (ns & acc[..., 0::2, :])
    return acc[..., 0, :]


def sc_mac_mux_ref(a_chunks: np.ndarray, wpos_q: np.ndarray, wneg_q: np.ndarray) -> np.ndarray:
    """Full bitwise oracle, mux mode.  a_chunks (B, C, NL) u8; w (M, C, NL) u8."""
    B, C, NL = a_chunks.shape
    M = wpos_q.shape[0]
    depth = int(math.log2(NL))
    a_str = encode_np(a_chunks.reshape(-1), T_ACT).reshape(B, C, NL, LANES)
    wpos = encode_weights_mux(wpos_q, depth)
    wneg = encode_weights_mux(wneg_q, depth)
    out = np.zeros((B, M), dtype=np.int64)
    for b in range(B):
        p_pos = a_str[b, None] & wpos  # (M, C, NL, LANES)
        p_neg = a_str[b, None] & wneg
        r_pos = mux_tree_np(p_pos, depth)  # (M, C, LANES)
        r_neg = mux_tree_np(p_neg, depth)
        pc_pos = popcount_u32(r_pos).astype(np.int64).sum(axis=(-1, -2))
        pc_neg = popcount_u32(r_neg).astype(np.int64).sum(axis=(-1, -2))
        out[b] = pc_pos - pc_neg
    return out.astype(np.int32)


def sc_mac_mux_diagonal(a_chunks: np.ndarray, wpos_q: np.ndarray, wneg_q: np.ndarray) -> np.ndarray:
    """Closed-form oracle, mux mode:
    raw[b,m] = sum_{c,i} [i < a[c, i mod NL]] & [T_WGT_D[i] < w[m, c, i mod NL]].
    """
    B, C, NL = a_chunks.shape
    depth = int(math.log2(NL))
    r = STREAM_BITS // NL
    t_wgt = wgt_thresholds(depth)
    a_pos = np.tile(a_chunks, (1, 1, r))  # (B, C, 256)
    wp_pos = np.tile(wpos_q, (1, 1, r))
    wn_pos = np.tile(wneg_q, (1, 1, r))
    a_bit = (T_ACT[None, None, :] < a_pos).astype(np.int32)
    w_diff = ((t_wgt[None, None, :] < wp_pos).astype(np.int32)
              - (t_wgt[None, None, :] < wn_pos).astype(np.int32))
    return (a_bit.reshape(B, -1) @ w_diff.reshape(wpos_q.shape[0], -1).T).astype(np.int32)


def float_mac_mux(a_chunks: np.ndarray, wpos_q: np.ndarray, wneg_q: np.ndarray) -> np.ndarray:
    """E[raw] in mux mode: R * sum_j a_j * w_j / 65536, R = 256/NL."""
    NL = a_chunks.shape[-1]
    r = STREAM_BITS // NL
    a = a_chunks.astype(np.float64)
    w = wpos_q.astype(np.float64) - wneg_q.astype(np.float64)
    return np.einsum("bcj,mcj->bm", a, w) * r / 65536.0


def mux_chunk_layout(n: int) -> tuple[int, int, int]:
    """Chunking rule for an n-input layer in mux mode: (C, NL, depth)."""
    if n <= STREAM_BITS:
        d = max(1, int(np.ceil(np.log2(n)))) if n > 1 else 1
        return 1, 1 << d, d
    return -(-n // STREAM_BITS), STREAM_BITS, 8


def mux_chunk_pad(values: np.ndarray) -> np.ndarray:
    """Pad the last axis per :func:`mux_chunk_layout`, reshape (..., C, NL)."""
    n = values.shape[-1]
    c, nl, _ = mux_chunk_layout(n)
    pad = c * nl - n
    if pad:
        values = np.pad(values, [(0, 0)] * (values.ndim - 1) + [(0, pad)])
    return values.reshape(*values.shape[:-1], c, nl)
