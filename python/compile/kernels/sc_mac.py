"""L1 Pallas kernels: ODIN's bit-parallel stochastic MAC.

This is the compute hot-spot of the paper mapped to the Pallas programming
model.  One 256-bit PCRAM line (= one stochastic stream) is 8 uint32 lanes;
the kernels perform, per (activation-tile, neuron-tile) grid cell, exactly
the bit-parallel operations ODIN's modified PCRAM bank performs:

  1. ``B_TO_S``   — encode u8 operand values into 256-bit streams by
                    comparing against the SRAM-LUT threshold permutation;
  2. ``ANN_MUL``  — bit-parallel AND between activation and weight streams
                    (PINATUBO simultaneous-row-activation read);
  3. ``ANN_ACC``  — accumulation, in one of two modes (sc_common.py):
                    ``binary`` (default): popcount every product stream and
                    sum in the pop-counter's binary adder;
                    ``mux`` (paper-faithful ablation): a depth-D MUX tree,
                    each MUX decomposed into (s AND a) OR (s' AND b), the
                    paper's Fig. 2(b)/5(c) with s = 0.5;
  4. ``S_TO_B``   — SWAR popcount (the PISO + level-counter block).

Weights arrive *pre-encoded* as packed streams (the Rust coordinator encodes
them once at model-load time with the bit-identical routine in
``rust/src/stochastic/``); activations are encoded in-kernel because they
change per request — mirroring the hardware, where weight streams persist in
the Compute Partition while activations are converted per inference.

Signed weights use dual-rail (w = w_pos - w_neg, both unipolar); the binary
subtraction happens after popcount, in the binary domain, like the paper's
post-``S_TO_B`` binary logic.

Grid/tiling: TM = 32 output neurons per block — the paper's ``S_TO_B``
granularity ("results of at least 32 neurons"); TB = 8 activation rows.

Kernels must run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); they lower to plain vectorized HLO which XLA CPU compiles.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .sc_common import (
    LANES,
    N_ROT,
    ROT_STRIDE,
    STREAM_BITS,
    T_WGT,
    mux_select_masks,
    wgt_thresholds,
)

# Tile sizes. TM matches the paper's 32-neuron S_TO_B batch; TB covers either
# a request micro-batch or an im2col patch tile.
TB = 8
TM = 32

_S_MASKS = mux_select_masks()  # (8, LANES) uint32, level-k MUX selects


def _popcount_u32(v: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount of a uint32 array (models the PISO + level counter)."""
    c1 = jnp.uint32(0x55555555)
    c2 = jnp.uint32(0x33333333)
    c4 = jnp.uint32(0x0F0F0F0F)
    m = jnp.uint32(0x01010101)
    v = v - ((v >> jnp.uint32(1)) & c1)
    v = (v & c2) + ((v >> jnp.uint32(2)) & c2)
    v = (v + (v >> jnp.uint32(4))) & c4
    return (v * m) >> jnp.uint32(24)


def _encode_act_streams(vals_u8: jnp.ndarray) -> jnp.ndarray:
    """B_TO_S for activations: (...,) u8 -> (..., LANES) packed uint32.

    T_ACT is the identity permutation, so stream bit i = (i < v): the
    comparison against a broadcast iota *is* the SRAM LUT row readout.
    popcount(stream(v)) == v exactly.
    """
    iota = jax.lax.broadcasted_iota(jnp.uint8, (STREAM_BITS,), 0)
    bits = (iota < vals_u8[..., None]).astype(jnp.uint32)  # (..., 256)
    bits = bits.reshape(*vals_u8.shape, LANES, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (LANES, 32), 1)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# Binary accumulation mode (default serve path)
# ---------------------------------------------------------------------------

def _sc_mac_kernel(a_ref, wpos_ref, wneg_ref, out_ref):
    """One grid cell, binary mode: TB rows x TM neurons x N operands.

    a_ref:    (TB, N) u8            activation values (zero padded)
    wpos_ref: (TM, N, LANES) u32    positive-rail weight streams, pre-rotated
    wneg_ref: (TM, N, LANES) u32    negative-rail weight streams, pre-rotated
    out_ref:  (TB, TM) i32          raw popcount difference (pos - neg)
    """
    a = a_ref[...]
    wpos = wpos_ref[...]
    wneg = wneg_ref[...]

    # B_TO_S for activations (weights are pre-encoded).
    a_str = _encode_act_streams(a)  # (TB, N, LANES)

    # ANN_MUL: bit-parallel AND, broadcast over (TB, TM).
    a_b = a_str[:, None]  # (TB, 1, N, LANES)
    p_pos = a_b & wpos[None]  # (TB, TM, N, LANES)
    p_neg = a_b & wneg[None]

    # S_TO_B per product + binary accumulate (pop counter's adder).
    pc_pos = _popcount_u32(p_pos).astype(jnp.int32).sum(axis=(-1, -2))
    pc_neg = _popcount_u32(p_neg).astype(jnp.int32).sum(axis=(-1, -2))
    out_ref[...] = pc_pos - pc_neg


def sc_mac(a_vals: jnp.ndarray, wpos: jnp.ndarray, wneg: jnp.ndarray) -> jnp.ndarray:
    """Bit-parallel stochastic MAC, binary accumulation (faithful emulation).

    a_vals:   (B, N) uint8 — activation values
    wpos/wneg: (M, N, LANES) uint32 — weight streams encoded against T_WGT
              and rotated by rot_amount(j) (see ref.encode_weights)
    returns:  (B, M) int32 — raw popcount difference; E[raw] = sum(a*w)/256,
              so the caller rescales by 256 * s_a * s_w (see model.py)

    B must be a multiple of TB and M a multiple of TM (model.py pads).
    """
    B, N = a_vals.shape
    M = wpos.shape[0]
    assert B % TB == 0 and M % TM == 0, (B, M)

    grid = (B // TB, M // TM)
    return pl.pallas_call(
        _sc_mac_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TB, N), lambda i, j: (i, 0)),
            pl.BlockSpec((TM, N, LANES), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((TM, N, LANES), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((TB, TM), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.int32),
        interpret=True,
    )(a_vals, wpos, wneg)


def cnt16_table() -> jnp.ndarray:
    """(N_ROT, 256, 256) i32 table: CNT[r, a, w] = popcount(enc_a(a) &
    rot_{ROT_STRIDE*r}(enc_w(w))) — built from iotas so it lives as cheap
    ops, not a 4 MB constant, inside the lowered HLO."""
    ii = np.arange(STREAM_BITS)
    abit = jnp.asarray((ii[None, :] < ii[:, None]).astype(np.float32))  # (a, i)
    tables = []
    for r in range(N_ROT):
        tw = T_WGT[(ii + ROT_STRIDE * r) % STREAM_BITS]
        wbit = jnp.asarray((tw[None, :] < ii[:, None]).astype(np.float32))  # (w, i)
        tables.append(jax.lax.dot_general(abit, wbit, (((1,), (1,)), ((), ()))))
    return jnp.stack(tables).astype(jnp.int32)  # (16, 256, 256)


def sc_mac_fast(a_vals: jnp.ndarray, wpos_q: jnp.ndarray, wneg_q: jnp.ndarray) -> jnp.ndarray:
    """Algebraically-reduced stochastic MAC (the optimized serve path).

    The popcount of a product stream is a dot product of indicator
    vectors, so the whole MAC collapses to one dense contraction:

        raw[b, m] = sum_{j, i} [i < a[b, j]] * ([TW[j, i] < wpos[m, j]]
                                              - [TW[j, i] < wneg[m, j]])

    with TW[j, i] = T_WGT[(i + rot(j)) mod 256] the per-operand rotated
    weight LUT.  *Bit-identical* to ``sc_mac`` (proved by
    python/tests/test_kernel.py and the Rust cross-check) while never
    materializing a stream.  Counts stay below 2^24 so the f32 matmul is
    exact.  (An equivalent CNT16 table-gather form exists —
    ``cnt16_table`` — but xla_extension 0.5.1, the Rust runtime's XLA,
    miscompiles large gathers; the dot_general form lowers to plain
    matmuls that execute correctly everywhere.)

    Takes u8 weight *values* (M, N), not packed streams.  Row-chunks the
    activation side through ``lax.map`` so conv-sized batches stay within
    memory.
    """
    B, N = a_vals.shape
    M = wpos_q.shape[0]
    ii = np.arange(STREAM_BITS)
    tw = np.stack([T_WGT[(ii + (ROT_STRIDE * (j % N_ROT))) % STREAM_BITS] for j in range(N)])
    tw = jnp.asarray(tw, dtype=jnp.uint8)  # (N, 256)
    iota = jnp.arange(STREAM_BITS, dtype=jnp.uint8)

    w_diff = (
        (tw[None] < wpos_q[:, :, None]).astype(jnp.float32)
        - (tw[None] < wneg_q[:, :, None]).astype(jnp.float32)
    ).reshape(M, N * STREAM_BITS)

    def block(a_blk):
        a_bit = (iota[None, None, :] < a_blk[:, :, None]).astype(jnp.float32)
        a_bit = a_bit.reshape(a_blk.shape[0], N * STREAM_BITS)
        return jax.lax.dot_general(a_bit, w_diff, (((1,), (1,)), ((), ())))

    chunk = 2048
    if B <= chunk:
        raw = block(a_vals)
    else:
        nb = -(-B // chunk)
        a_p = jnp.pad(a_vals, ((0, nb * chunk - B), (0, 0)))
        raw = jax.lax.map(block, a_p.reshape(nb, chunk, N)).reshape(nb * chunk, M)[:B]
    return raw.astype(jnp.int32)



# ---------------------------------------------------------------------------
# MUX-tree accumulation mode (paper-faithful ablation)
# ---------------------------------------------------------------------------

def _mux_tree(products: jnp.ndarray, s_masks: jnp.ndarray, depth: int) -> jnp.ndarray:
    """ANN_ACC, mux mode: reduce NL = 2**depth product streams -> 1 stream.

    ``products``: (..., NL, LANES) uint32.  Level k select mask
    s_k[i] = (i >> k) & 1, so the surviving bit i samples product stream
    ``i mod NL`` at position ``i``.  Each MUX is (s AND right) OR
    (NOT s AND left) — two ANDs and an OR, the paper's Fig. 5(c).
    """
    acc = products
    for k in range(depth):
        s = s_masks[k]  # (LANES,)
        ns = s ^ jnp.uint32(0xFFFFFFFF)
        left = acc[..., 0::2, :]
        right = acc[..., 1::2, :]
        acc = (s & right) | (ns & left)
    return acc[..., 0, :]  # (..., LANES)


def _make_mux_kernel(depth: int):
    def kernel(a_ref, wpos_ref, wneg_ref, s_masks_ref, out_ref):
        """One grid cell, mux mode: C chunks of NL = 2**depth operands.

        a_ref:    (TB, C, NL) u8           activation values (zero padded)
        wpos_ref: (TM, C, NL, LANES) u32   positive-rail weight streams
        wneg_ref: (TM, C, NL, LANES) u32   negative-rail weight streams
        s_masks_ref: (8, LANES) u32        packed MUX selects per level
        out_ref:  (TB, TM) i32             raw popcount diff (pos - neg)
        """
        a = a_ref[...]
        wpos = wpos_ref[...]
        wneg = wneg_ref[...]
        s_masks = s_masks_ref[...]

        a_str = _encode_act_streams(a)  # (TB, C, NL, LANES)
        a_b = a_str[:, None]  # (TB, 1, C, NL, LANES)
        p_pos = a_b & wpos[None]  # (TB, TM, C, NL, LANES)
        p_neg = a_b & wneg[None]

        r_pos = _mux_tree(p_pos, s_masks, depth)  # (TB, TM, C, LANES)
        r_neg = _mux_tree(p_neg, s_masks, depth)

        pc_pos = _popcount_u32(r_pos).astype(jnp.int32).sum(axis=(-1, -2))
        pc_neg = _popcount_u32(r_neg).astype(jnp.int32).sum(axis=(-1, -2))
        out_ref[...] = pc_pos - pc_neg

    return kernel


def sc_mac_mux(a_chunks: jnp.ndarray, wpos: jnp.ndarray, wneg: jnp.ndarray) -> jnp.ndarray:
    """Paper-faithful MUX-tree MAC over chunked operands.

    a_chunks: (B, C, NL) uint8, NL = 2**depth; wpos/wneg: (M, C, NL, LANES)
    uint32 encoded against ``wgt_thresholds(depth)``.  Returns (B, M) i32;
    E[raw] = R * sum(a*w)/65536 with R = 256/NL.
    """
    B, C, NL = a_chunks.shape
    M = wpos.shape[0]
    depth = int(math.log2(NL))
    assert 1 << depth == NL, NL
    assert B % TB == 0 and M % TM == 0, (B, M)

    grid = (B // TB, M // TM)
    s_masks = jnp.asarray(_S_MASKS, dtype=jnp.uint32)
    return pl.pallas_call(
        _make_mux_kernel(depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TB, C, NL), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((TM, C, NL, LANES), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((TM, C, NL, LANES), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((8, LANES), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TB, TM), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.int32),
        interpret=True,
    )(a_chunks, wpos, wneg, s_masks)


def sc_mac_mux_fast(a_chunks: jnp.ndarray, wpos_q: jnp.ndarray, wneg_q: jnp.ndarray) -> jnp.ndarray:
    """Closed form of the MUX-tree path (bit-identical to ``sc_mac_mux``):
    raw[b,m] = sum_{c,i} [i < a[c, i mod NL]] & [T_WGT_D[i] < w[m, c, i mod NL]].
    """
    B, C, NL = a_chunks.shape
    M = wpos_q.shape[0]
    depth = int(math.log2(NL))
    r = STREAM_BITS // NL
    t_act = jnp.arange(STREAM_BITS, dtype=jnp.uint8)
    t_wgt = jnp.asarray(wgt_thresholds(depth), dtype=jnp.uint8)
    a_pos = jnp.tile(a_chunks, (1, 1, r))  # (B, C, 256)
    wp_pos = jnp.tile(wpos_q, (1, 1, r))  # (M, C, 256)
    wn_pos = jnp.tile(wneg_q, (1, 1, r))
    a_bit = (t_act < a_pos).astype(jnp.float32)
    w_diff = (t_wgt < wp_pos).astype(jnp.float32) - (t_wgt < wn_pos).astype(jnp.float32)
    raw = jax.lax.dot_general(
        a_bit.reshape(B, -1), w_diff.reshape(M, -1), (((1,), (1,)), ((), ())))
    return raw.astype(jnp.int32)
