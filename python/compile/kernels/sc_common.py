"""Shared stochastic-number (SN) constants and helpers.

ODIN encodes every 8-bit operand as a 256-bit stochastic stream stored in a
PCRAM row block (the paper's Compute Partition).  We use deterministic
low-discrepancy threshold sequences instead of LFSR noise so that the Pallas
kernel, the pure-numpy oracle (ref.py), and the Rust functional simulator
(rust/src/stochastic/) are *bit-exact* against each other:

    stream(v)[i] = 1  iff  T[i] < v

with ``T`` a permutation of 0..255.  Because T is a permutation,
``popcount(stream(v)) == v`` exactly (unbiased encoding) — the property every
cross-layer test leans on.

Threshold design.  Activations use the identity permutation T_ACT[i] = i;
weights use the bit-reversal permutation T_WGT[i] = bitrev8(i).  The pair
(i, bitrev8(i)) is the 256-point 2D Hammersley set, so a full-stream AND
popcount estimates a*w/256 with low-discrepancy error (|err| <= ~3 counts).
(A naive "same sequence XOR constant" choice anti-correlates the two
streams — e.g. thresholds t < 128 and t^0x80 < 128 are mutually exclusive —
and destroys MAC accuracy; tests pin this property.)

Accumulation modes (the repo's central accuracy/cost ablation, DESIGN.md §4):

* ``binary`` (default) — every product stream is popcounted (``S_TO_B``)
  and the N popcounts are summed by the binary adder in the pop-counter
  block.  To decorrelate the deterministic quadrature bias across operands,
  operand j's weight stream is stored rotated by ROT_STRIDE*(j mod N_ROT)
  bit positions (rotation preserves popcount; in hardware the write of the
  LUT row simply starts at a per-row column offset).  ~1-4% relative MAC
  error; costs one S_TO_B per 32 products.

* ``mux`` (paper-faithful) — a depth-D MUX tree reduces NL = 2**D product
  streams to one stream which is popcounted once per chunk.  Bit i of the
  reduced stream samples product ``i mod NL`` at position i.  Cheapest in
  S_TO_B traffic, but the 1/NL result scaling makes wide layers drown in
  sampling noise — exactly the trade-off the ablation benches quantify.

In hardware terms these tables are the contents written into the paper's
256x256 SRAM conversion LUT; they are programmed once at model-load time.
"""

from __future__ import annotations

import numpy as np

# Stream geometry: 256 bits = one PCRAM line = 8 * 32-bit lanes.
STREAM_BITS = 256
LANES = STREAM_BITS // 32  # 8 packed uint32 words per stream
MAX_DEPTH = 8

# Binary-mode rotation schedule: operand j's weight stream is rotated left by
# ROT_STRIDE * (j mod N_ROT) bit positions.  ROT_STRIDE is a multiple of 32
# would allow word-granular rotation; 16 gives finer decorrelation and still
# only costs a half-word shift in the PISO path.
N_ROT = 16
ROT_STRIDE = 16


def rot_amount(j: int) -> int:
    """Bit rotation applied to operand j's weight stream (binary mode)."""
    return ROT_STRIDE * (j % N_ROT)


def bitrev8(i: int) -> int:
    """Reverse the 8 bits of ``i`` (van der Corput radix-2 index)."""
    i &= 0xFF
    i = ((i & 0x0F) << 4) | ((i & 0xF0) >> 4)
    i = ((i & 0x33) << 2) | ((i & 0xCC) >> 2)
    i = ((i & 0x55) << 1) | ((i & 0xAA) >> 1)
    return i


def depth_for(n: int) -> int:
    """MUX-tree depth for an n-operand chunk: smallest D with 2**D >= n,
    capped at 8 (chunks never hold more than 256 operands)."""
    assert 1 <= n <= STREAM_BITS, n
    return max(1, int(np.ceil(np.log2(n)))) if n > 1 else 1


def act_thresholds() -> np.ndarray:
    """T_ACT: identity permutation (the activation-side SRAM LUT)."""
    return np.arange(STREAM_BITS, dtype=np.uint8)


def wgt_thresholds(depth: int) -> np.ndarray:
    """T_WGT for a layer whose MUX tree has the given depth (1..8)."""
    assert 1 <= depth <= MAX_DEPTH, depth
    nl = 1 << depth
    i = np.arange(STREAM_BITS, dtype=np.uint32)
    swapped = (i >> depth) | ((i & (nl - 1)) << (8 - depth))
    return np.array([bitrev8(int(x)) for x in swapped], dtype=np.uint8)


# Identity LUT, used everywhere for activations.
T_ACT = act_thresholds()

# Bit-reversal LUT, used for weights in binary mode (and by depth-8 chunks
# in mux mode; wgt_thresholds(8) == bitrev8).
T_WGT = np.array([bitrev8(i) for i in range(STREAM_BITS)], dtype=np.uint8)


def pack_bits_u32(bits: np.ndarray) -> np.ndarray:
    """Pack a (..., 256) uint8/bool bit array into (..., 8) uint32.

    Bit ``i`` of the stream lands in word ``i // 32`` at position ``i % 32``
    (LSB-first), matching the Rust packing in stochastic/stream.rs.
    """
    bits = np.asarray(bits, dtype=np.uint32).reshape(*bits.shape[:-1], LANES, 32)
    shifts = np.arange(32, dtype=np.uint32)
    return (bits << shifts).sum(axis=-1, dtype=np.uint32)


def unpack_bits_u32(words: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pack_bits_u32`: (..., 8) uint32 -> (..., 256) uint8."""
    shifts = np.arange(32, dtype=np.uint32)
    bits = (words[..., None] >> shifts) & 1
    return bits.reshape(*words.shape[:-1], STREAM_BITS).astype(np.uint8)


def encode_np(values: np.ndarray, thresholds: np.ndarray) -> np.ndarray:
    """Reference numpy encoder: (...,) u8 values -> (..., 8) u32 streams."""
    bits = (thresholds[None, :] < np.asarray(values, dtype=np.uint8).reshape(-1, 1))
    packed = pack_bits_u32(bits.astype(np.uint8))
    return packed.reshape(*np.shape(values), LANES)


def mux_select_masks() -> np.ndarray:
    """Packed select streams for MUX-tree levels 0..7.

    Level-k select is ``s_k[i] = (i >> k) & 1`` over bit index i in 0..255;
    each has popcount exactly 128 (the paper's s = 0.5).  A depth-D tree
    uses levels 0..D-1.  Returned shape (8, LANES) uint32.
    """
    i = np.arange(STREAM_BITS, dtype=np.uint32)
    masks = np.stack([((i >> k) & 1).astype(np.uint8) for k in range(8)])
    return pack_bits_u32(masks)
