"""Tiny TLV tensor container shared between the Python compile path and the
Rust runtime (rust/src/runtime/tensorfile.rs parses the same format).

Layout (all little-endian):
  u32 magic 0x4F44_494E ("ODIN")
  u32 version (1)
  u32 tensor count
  per tensor:
    u32 name length, name bytes (utf-8)
    u32 dtype  (0 = u8, 1 = i16, 2 = f32, 3 = u32, 4 = i32)
    u32 ndim, u32 dims[ndim]
    raw data bytes (C order, little-endian)
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = 0x4F44494E
_DTYPES = {0: np.uint8, 1: np.int16, 2: np.float32, 3: np.uint32, 4: np.int32}
_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


def write_tensors(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(struct.pack("<III", MAGIC, 1, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = _CODES[arr.dtype]
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<II", code, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def read_tensors(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        magic, version, count = struct.unpack("<III", f.read(12))
        assert magic == MAGIC and version == 1, (magic, version)
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<II", f.read(8))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            dt = np.dtype(_DTYPES[code])
            n = int(np.prod(dims)) if ndim else 1
            out[name] = np.frombuffer(f.read(n * dt.itemsize), dtype=dt).reshape(dims)
    return out
