"""Dataset determinism, tensorfile container, and artifact manifest checks."""

import json
import os
import tempfile

import numpy as np
import pytest

from compile.dataset import make_dataset, train_test_split
from compile.tensorfile import read_tensors, write_tensors

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first")


class TestDataset:
    def test_deterministic(self):
        a, la = make_dataset(64, seed=42)
        b, lb = make_dataset(64, seed=42)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(la, lb)

    def test_seed_changes_data(self):
        a, _ = make_dataset(64, seed=1)
        b, _ = make_dataset(64, seed=2)
        assert not np.array_equal(a, b)

    def test_shapes_and_ranges(self):
        x, y = make_dataset(100, seed=0)
        assert x.shape == (100, 28, 28) and x.dtype == np.uint8
        assert y.shape == (100,) and set(np.unique(y)) <= set(range(10))

    def test_all_classes_present(self):
        _, y = make_dataset(500, seed=0)
        assert len(np.unique(y)) == 10

    def test_images_nontrivial(self):
        x, _ = make_dataset(32, seed=0)
        assert (x.reshape(32, -1).max(axis=1) > 100).all()
        assert x.mean() < 128  # mostly background


class TestTensorfile:
    def test_roundtrip_all_dtypes(self):
        rng = np.random.default_rng(0)
        tensors = {
            "u8": rng.integers(0, 256, (3, 4), dtype=np.uint8),
            "i16": rng.integers(-1000, 1000, (5,), dtype=np.int16),
            "f32": rng.normal(size=(2, 3, 4)).astype(np.float32),
            "u32": rng.integers(0, 2**32, (7,), dtype=np.uint32),
            "i32": rng.integers(-2**31, 2**31, (2, 2), dtype=np.int32),
        }
        with tempfile.NamedTemporaryFile(suffix=".bin") as f:
            write_tensors(f.name, tensors)
            back = read_tensors(f.name)
        assert set(back) == set(tensors)
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])
            assert back[k].dtype == tensors[k].dtype

    def test_scalar_and_empty_shapes(self):
        with tempfile.NamedTemporaryFile(suffix=".bin") as f:
            write_tensors(f.name, {"x": np.zeros((0, 4), np.float32)})
            back = read_tensors(f.name)
        assert back["x"].shape == (0, 4)


@needs_artifacts
class TestArtifacts:
    def test_manifest_covers_files(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            manifest = json.load(f)
        for name in manifest:
            assert os.path.exists(os.path.join(ART, f"{name}.hlo.txt")), name

    def test_expected_artifact_set(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            manifest = json.load(f)
        for arch in ("cnn1", "cnn2"):
            for b in (1, 8, 32):
                assert f"{arch}_fast_b{b}" in manifest
            assert f"{arch}_sc_b1" in manifest
            assert f"{arch}_float_b1" in manifest
        assert "sc_tile" in manifest and "sc_tile_fast" in manifest

    def test_arg_specs_consistent_with_model(self):
        from compile import model as M
        with open(os.path.join(ART, "manifest.json")) as f:
            manifest = json.load(f)
        spec = manifest["cnn1_fast_b8"]
        shapes = M.sc_weight_arg_shapes("cnn1", fast=True, batch=8)
        assert len(spec["args"]) == len(shapes)
        for got, want in zip(spec["args"], shapes):
            assert tuple(got["shape"]) == want.shape

    def test_hlo_text_is_parseable_entry(self):
        """Cheap sanity: the artifact is HLO text with an ENTRY computation."""
        for name in ("cnn1_fast_b1", "sc_tile_fast"):
            with open(os.path.join(ART, f"{name}.hlo.txt")) as f:
                text = f.read()
            assert "ENTRY" in text and "ROOT" in text

    def test_weights_bin_has_required_tensors(self):
        for arch in ("cnn1", "cnn2"):
            t = read_tensors(os.path.join(ART, "weights", f"{arch}.bin"))
            for name in ("scales", "conv_q", "fc1_q", "fc2_q",
                         "conv_b", "fc1_b", "fc2_b",
                         "conv_w", "fc1_w", "fc2_w"):
                assert name in t, (arch, name)
            assert t["scales"].shape == (6,)

    def test_test_split_matches_dataset_generator(self):
        data = read_tensors(os.path.join(ART, "data", "test.bin"))
        (_, _), (xte, yte) = train_test_split()
        np.testing.assert_array_equal(data["images"], xte)
        np.testing.assert_array_equal(data["labels"], yte)
