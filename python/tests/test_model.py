"""L2 correctness: quantized forward graphs, conv/pool plumbing, and the
faithful-vs-fast model-level equivalence."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.tensorfile import read_tensors

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _weights(arch):
    t = read_tensors(os.path.join(ART, "weights", f"{arch}.bin"))
    with open(os.path.join(ART, "weights", f"{arch}.json")) as f:
        meta = json.load(f)
    return t, meta["scales"]


def _sc_args(t, fast):
    from compile.kernels import ref as REF
    out = []
    for name in ("conv_q", "fc1_q", "fc2_q"):
        wp, wn = M.rails(t[name])
        wp_v, wn_v = M.weight_values(wp), M.weight_values(wn)
        if fast:
            out += [jnp.asarray(wp_v), jnp.asarray(wn_v)]
        else:
            out += [jnp.asarray(REF.encode_weights(wp_v)),
                    jnp.asarray(REF.encode_weights(wn_v))]
        out.append(jnp.asarray(t[name.replace("_q", "_b")]))
    return out


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "weights", "cnn1.bin")),
    reason="run `make artifacts` first")


class TestIm2col:
    def test_same_padding_shape(self):
        img = jnp.zeros((2, 28, 28), jnp.float32)
        p = M.im2col(img, 5, "same")
        assert p.shape == (2, 784, 25)

    def test_valid_shape(self):
        img = jnp.zeros((2, 28, 28), jnp.float32)
        p = M.im2col(img, 7, "valid")
        assert p.shape == (2, 484, 49)

    def test_matches_direct_convolution(self):
        rng = np.random.default_rng(0)
        img = rng.normal(size=(1, 10, 10)).astype(np.float32)
        ker = rng.normal(size=(3, 3)).astype(np.float32)
        p = np.asarray(M.im2col(jnp.asarray(img), 3, "valid"))  # (1, 64, 9)
        got = (p.reshape(-1, 9) @ ker.reshape(9, 1)).reshape(8, 8)
        want = np.zeros((8, 8), np.float32)
        for y in range(8):
            for x in range(8):
                want[y, x] = (img[0, y:y + 3, x:x + 3] * ker).sum()
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_patch_ordering_row_major(self):
        img = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4)
        p = np.asarray(M.im2col(img, 2, "valid"))
        # first patch = rows [[0,1],[4,5]] flattened dy-major
        np.testing.assert_array_equal(p[0, 0], [0, 1, 4, 5])


class TestMaxpool:
    def test_basic(self):
        x = jnp.asarray(np.arange(16, dtype=np.uint8).reshape(1, 4, 4, 1))
        y = np.asarray(M.maxpool2(x))
        np.testing.assert_array_equal(y[0, :, :, 0], [[5, 7], [13, 15]])

    def test_channels_independent(self):
        rng = np.random.default_rng(0)
        x = rng.integers(0, 256, (2, 8, 8, 3), dtype=np.uint8)
        y = np.asarray(M.maxpool2(jnp.asarray(x)))
        for c in range(3):
            yc = np.asarray(M.maxpool2(jnp.asarray(x[..., c:c + 1])))
            np.testing.assert_array_equal(y[..., c], yc[..., 0])


class TestQuantization:
    def test_quantize_roundtrip_error(self):
        rng = np.random.default_rng(0)
        w = rng.normal(scale=0.1, size=(50, 20)).astype(np.float32)
        q, s = M.quantize_weights(w)
        assert np.abs(q * s - w).max() <= s / 2 + 1e-7

    def test_rails_reconstruct(self):
        q = np.array([[-255, -1, 0, 1, 255]], np.int16)
        wp, wn = M.rails(q)
        np.testing.assert_array_equal(wp.astype(np.int32) - wn.astype(np.int32), q)

    def test_zero_weights(self):
        q, s = M.quantize_weights(np.zeros((4, 4), np.float32))
        assert (q == 0).all() and s > 0


@needs_artifacts
class TestForwardGraphs:
    @pytest.mark.parametrize("arch", ["cnn1", "cnn2"])
    def test_fast_shapes(self, arch):
        t, scales = _weights(arch)
        fwd = jax.jit(M.make_sc_fwd(arch, scales, fast=True))
        img = jnp.zeros((4, 28, 28), jnp.uint8)
        (logits,) = fwd(img, *_sc_args(t, fast=True))
        assert logits.shape == (4, 10)

    @pytest.mark.parametrize("arch", ["cnn1"])
    def test_faithful_equals_fast_model_level(self, arch):
        """The full faithful Pallas forward and the optimized gather forward
        produce *identical* logits — the model-level equivalence proof."""
        t, scales = _weights(arch)
        rng = np.random.default_rng(5)
        img = jnp.asarray(rng.integers(0, 256, (1, 28, 28), dtype=np.uint8))
        (fast,) = jax.jit(M.make_sc_fwd(arch, scales, fast=True))(
            img, *_sc_args(t, fast=True))
        (slow,) = M.make_sc_fwd(arch, scales, fast=False)(
            img, *_sc_args(t, fast=False))
        # Raw popcounts are bit-identical (test_kernel.py); the final f32
        # rescale may associate differently under jit, hence the epsilon.
        np.testing.assert_allclose(np.asarray(fast), np.asarray(slow),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("arch", ["cnn1", "cnn2"])
    def test_float_reference_accuracy(self, arch):
        """Float network reproduces the recorded training accuracy on a
        slice of the canonical test split."""
        t, scales = _weights(arch)
        data = read_tensors(os.path.join(ART, "data", "test.bin"))
        x, y = data["images"][:256], data["labels"][:256]
        fwd = jax.jit(M.make_float_fwd(arch))
        (logits,) = fwd(jnp.asarray(x.astype(np.float32) / 255.0),
                        jnp.asarray(t["conv_w"]), jnp.asarray(t["conv_b"]),
                        jnp.asarray(t["fc1_w"]), jnp.asarray(t["fc1_b"]),
                        jnp.asarray(t["fc2_w"]), jnp.asarray(t["fc2_b"]))
        acc = (np.argmax(np.asarray(logits), 1) == y).mean()
        assert acc > 0.9

    @pytest.mark.parametrize("arch", ["cnn1", "cnn2"])
    def test_stochastic_accuracy_tracks_float(self, arch):
        """Table 2's claim: 8-bit stochastic inference stays within a few
        points of float accuracy."""
        t, scales = _weights(arch)
        data = read_tensors(os.path.join(ART, "data", "test.bin"))
        x, y = data["images"][:256], data["labels"][:256]
        fwd = jax.jit(M.make_sc_fwd(arch, scales, fast=True))
        args = _sc_args(t, fast=True)
        correct = 0
        for i in range(0, len(x), 32):
            (logits,) = fwd(jnp.asarray(x[i:i + 32]), *args)
            correct += int((np.argmax(np.asarray(logits), 1) == y[i:i + 32]).sum())
        acc = correct / len(x)
        assert acc > 0.9

    def test_batch_one_matches_batch_many(self):
        t, scales = _weights("cnn1")
        rng = np.random.default_rng(9)
        imgs = rng.integers(0, 256, (4, 28, 28), dtype=np.uint8)
        args = _sc_args(t, fast=True)
        fwd = jax.jit(M.make_sc_fwd("cnn1", scales, fast=True))
        (batch,) = fwd(jnp.asarray(imgs), *args)
        for i in range(4):
            (one,) = fwd(jnp.asarray(imgs[i:i + 1]), *args)
            np.testing.assert_array_equal(np.asarray(one)[0], np.asarray(batch)[i])


@needs_artifacts
class TestArgShapes:
    @pytest.mark.parametrize("arch", ["cnn1", "cnn2"])
    @pytest.mark.parametrize("fast", [True, False])
    def test_sc_weight_arg_shapes_match_weights(self, arch, fast):
        t, _ = _weights(arch)
        shapes = M.sc_weight_arg_shapes(arch, fast=fast, batch=2)
        assert shapes[0].shape == (2, 28, 28)
        conv_wp = shapes[1]
        k, maps = M.ARCHS[arch]["k"], M.ARCHS[arch]["maps"]
        if fast:
            assert conv_wp.shape == (maps, k * k)
        else:
            assert conv_wp.shape == (maps, k * k, 8)
