"""L1 correctness: Pallas kernels vs pure-numpy oracles.

The cross-implementation equalities here are the spine of the whole repo:
  Pallas kernel == bitwise numpy oracle == closed-form table/diagonal
for both accumulation modes, over hypothesis-driven shape/value sweeps.
The same vectors are pinned by the Rust side (stochastic/ tests) through
golden files, so all three languages agree bit-for-bit.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref as REF
from compile.kernels import sc_mac as K
from compile.kernels import sc_common as C


def rails_from_signed(wq):
    return (np.clip(wq, 0, 255).astype(np.uint8),
            np.clip(-wq, 0, 255).astype(np.uint8))


# ---------------------------------------------------------------------------
# Encoding properties
# ---------------------------------------------------------------------------

class TestEncoding:
    def test_act_thresholds_is_identity_permutation(self):
        t = C.act_thresholds()
        assert sorted(t.tolist()) == list(range(256))
        assert (t == np.arange(256)).all()

    def test_wgt_thresholds_are_permutations_for_all_depths(self):
        for d in range(1, 9):
            t = C.wgt_thresholds(d)
            assert sorted(t.tolist()) == list(range(256)), f"depth {d}"

    def test_bitrev8_involution(self):
        for i in range(256):
            assert C.bitrev8(C.bitrev8(i)) == i

    @given(st.integers(0, 255))
    def test_encode_popcount_exact(self, v):
        """popcount(stream(v)) == v for every value and every LUT."""
        for t in (C.T_ACT, C.T_WGT, C.wgt_thresholds(3)):
            packed = C.encode_np(np.array([v], np.uint8), t)
            bits = C.unpack_bits_u32(packed)
            assert bits.sum() == v

    def test_pack_unpack_roundtrip(self):
        rng = np.random.default_rng(0)
        bits = rng.integers(0, 2, (5, 256)).astype(np.uint8)
        assert (C.unpack_bits_u32(C.pack_bits_u32(bits)) == bits).all()

    def test_mux_select_masks_popcount_half(self):
        """Every select stream encodes s = 0.5 exactly (popcount 128)."""
        masks = C.mux_select_masks()
        for k in range(8):
            assert C.unpack_bits_u32(masks[k]).sum() == 128

    def test_rotation_preserves_popcount(self):
        """Stream rotation (binary mode) never changes the encoded value."""
        w = np.full((1, C.N_ROT), 173, np.uint8)
        packed = REF.encode_weights(w)
        pcs = REF.popcount_u32(packed).sum(axis=-1)
        assert (pcs == 173).all()

    def test_xor_scramble_anticorrelation_pitfall(self):
        """Documents why T_WGT != T_ACT ^ const: the XOR-scrambled pair is
        catastrophically anti-correlated (the bug this design fixes)."""
        t_bad = C.T_ACT ^ 0x80
        # a = w = 128: true product 64, xor-scrambled estimate is 0
        cnt = int(((C.T_ACT < 128) & (t_bad < 128)).sum())
        assert cnt == 0
        # Hammersley pair is close to 64
        cnt_good = int(((C.T_ACT < 128) & (C.T_WGT < 128)).sum())
        assert abs(cnt_good - 64) <= 3


# ---------------------------------------------------------------------------
# Binary accumulation mode (default)
# ---------------------------------------------------------------------------

class TestBinaryMode:
    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 3).map(lambda x: 8 * x),
        m=st.integers(1, 2).map(lambda x: 32 * x),
        n=st.integers(1, 300),
        seed=st.integers(0, 2**31),
    )
    def test_three_way_bit_exact(self, b, m, n, seed):
        rng = np.random.default_rng(seed)
        a = rng.integers(0, 256, (b, n), dtype=np.uint8)
        wq = rng.integers(-255, 256, (m, n))
        wp, wn = rails_from_signed(wq)
        r_ref = REF.sc_mac_ref(a, wp, wn)
        r_tab = REF.sc_mac_table(a, wp, wn)
        np.testing.assert_array_equal(r_ref, r_tab)
        r_k = np.asarray(K.sc_mac(
            jnp.asarray(a),
            jnp.asarray(REF.encode_weights(wp)),
            jnp.asarray(REF.encode_weights(wn))))
        np.testing.assert_array_equal(r_k, r_ref)
        r_f = np.asarray(K.sc_mac_fast(jnp.asarray(a), jnp.asarray(wp), jnp.asarray(wn)))
        np.testing.assert_array_equal(r_f, r_ref)

    def test_zero_inputs_give_zero(self):
        a = np.zeros((8, 64), np.uint8)
        w = np.zeros((32, 64), np.uint8)
        assert (REF.sc_mac_ref(a, w, w) == 0).all()
        r = np.asarray(K.sc_mac_fast(jnp.asarray(a), jnp.asarray(w), jnp.asarray(w)))
        assert (r == 0).all()

    def test_max_inputs_give_exact_count(self):
        """a = w = 255 -> every product popcount is cnt(255,255) = 254
        (exactly 255*255/256 rounded by the Hammersley set)."""
        n = 16
        a = np.full((8, n), 255, np.uint8)
        wp = np.full((32, n), 255, np.uint8)
        wn = np.zeros((32, n), np.uint8)
        raw = REF.sc_mac_ref(a, wp, wn)
        expect = REF.float_mac(a, wp, wn)
        assert np.abs(raw - expect).max() <= 3 * n

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31))
    def test_sc_error_bound(self, seed):
        """|raw - E[raw]| stays within the low-discrepancy bound ~3/operand."""
        rng = np.random.default_rng(seed)
        n = 200
        a = rng.integers(0, 256, (8, n), dtype=np.uint8)
        wq = rng.integers(-255, 256, (32, n))
        wp, wn = rails_from_signed(wq)
        raw = REF.sc_mac_table(a, wp, wn)
        expect = REF.float_mac(a, wp, wn)
        assert np.abs(raw - expect).max() <= 3.0 * n

    def test_dual_rail_antisymmetry(self):
        """Swapping the rails negates the output exactly."""
        rng = np.random.default_rng(1)
        a = rng.integers(0, 256, (8, 50), dtype=np.uint8)
        wq = rng.integers(-255, 256, (32, 50))
        wp, wn = rails_from_signed(wq)
        np.testing.assert_array_equal(
            REF.sc_mac_table(a, wp, wn), -REF.sc_mac_table(a, wn, wp))

    def test_cnt16_table_matches_jax(self):
        t_np = REF.cnt16_table_np()
        t_jx = np.asarray(K.cnt16_table())
        np.testing.assert_array_equal(t_np, t_jx)

    def test_cnt_table_monotone(self):
        """CNT[r, a, w] is nondecreasing in both a and w (step functions)."""
        t = REF.cnt16_table_np()
        assert (np.diff(t, axis=1) >= 0).all()
        assert (np.diff(t, axis=2) >= 0).all()
        assert (t[:, 0, :] == 0).all() and (t[:, :, 0] == 0).all()


# ---------------------------------------------------------------------------
# MUX-tree accumulation mode (paper-faithful ablation)
# ---------------------------------------------------------------------------

class TestMuxMode:
    @settings(max_examples=12, deadline=None)
    @given(
        depth=st.integers(1, 8),
        c=st.integers(1, 3),
        seed=st.integers(0, 2**31),
    )
    def test_three_way_bit_exact(self, depth, c, seed):
        nl = 1 << depth
        rng = np.random.default_rng(seed)
        a = rng.integers(0, 256, (8, c, nl), dtype=np.uint8)
        wq = rng.integers(-255, 256, (32, c, nl))
        wp, wn = rails_from_signed(wq)
        r_ref = REF.sc_mac_mux_ref(a, wp, wn)
        r_diag = REF.sc_mac_mux_diagonal(a, wp, wn)
        np.testing.assert_array_equal(r_ref, r_diag)
        r_k = np.asarray(K.sc_mac_mux(
            jnp.asarray(a),
            jnp.asarray(REF.encode_weights_mux(wp, depth)),
            jnp.asarray(REF.encode_weights_mux(wn, depth))))
        np.testing.assert_array_equal(r_k, r_ref)
        r_f = np.asarray(K.sc_mac_mux_fast(jnp.asarray(a), jnp.asarray(wp), jnp.asarray(wn)))
        np.testing.assert_array_equal(r_f, r_ref)

    def test_mux_output_bounded_by_stream(self):
        """A depth-D chunk's contribution can never exceed 256 per rail —
        the 1/NL scaling that motivates the binary-mode ablation."""
        a = np.full((8, 1, 256), 255, np.uint8)
        wp = np.full((32, 1, 256), 255, np.uint8)
        wn = np.zeros_like(wp)
        raw = REF.sc_mac_mux_ref(a, wp, wn)
        assert raw.max() <= 256

    def test_mux_chunk_layout(self):
        assert REF.mux_chunk_layout(25) == (1, 32, 5)
        assert REF.mux_chunk_layout(256) == (1, 256, 8)
        assert REF.mux_chunk_layout(257) == (2, 256, 8)
        assert REF.mux_chunk_layout(784) == (4, 256, 8)

    def test_mux_noise_exceeds_binary_noise_on_wide_layers(self):
        """The quantified reason binary mode is the default: on a 784-input
        layer the mux path's absolute error dwarfs the binary path's."""
        rng = np.random.default_rng(7)
        n = 784
        a = rng.integers(0, 150, (8, n), dtype=np.uint8)
        wq = rng.integers(-200, 201, (32, n))
        wp, wn = rails_from_signed(wq)
        err_bin = np.abs(REF.sc_mac_table(a, wp, wn) * 256.0
                         - a.astype(np.int64) @ (wq.T)).mean()
        a_c = REF.mux_chunk_pad(a)
        wp_c = REF.mux_chunk_pad(wp)
        wn_c = REF.mux_chunk_pad(wn)
        err_mux = np.abs(REF.sc_mac_mux_diagonal(a_c, wp_c, wn_c) * 65536.0
                         - a.astype(np.int64) @ (wq.T)).mean()
        assert err_mux > 4 * err_bin


# ---------------------------------------------------------------------------
# SWAR popcount
# ---------------------------------------------------------------------------

class TestPopcount:
    @given(st.integers(0, 2**32 - 1))
    def test_popcount_u32(self, v):
        got = int(REF.popcount_u32(np.array([v], np.uint32))[0])
        assert got == bin(v).count("1")

    def test_popcount_vector(self):
        rng = np.random.default_rng(3)
        v = rng.integers(0, 2**32, 1000, dtype=np.uint32)
        got = REF.popcount_u32(v)
        want = np.array([bin(int(x)).count("1") for x in v])
        np.testing.assert_array_equal(got, want)
